//! Graphulo TableMult — server-side sparse matrix multiply inside the
//! key-value store (Hutchison et al. 2015), the operation of Figure 2.
//!
//! `C += A^T * B` where A and B are D4M tables whose *rows* are the
//! contraction dimension: for every row key `k` present in both tables,
//! every pair of entries `A(k, i) = a` and `B(k, j) = b` contributes a
//! partial product `a*b` to `C(i, j)`. Partial products are written
//! through a [`BatchWriter`] into C and folded by the store's summing
//! combiner at scan time — exactly the Accumulo iterator design.
//!
//! The decisive property (and the point of Figure 2): server memory is
//! bounded by **one row of A + one row of B + the write buffer**,
//! independent of the output size — while client-side D4M must hold
//! A, B *and* C in RAM.

// unwrap/expect are disallowed repo-wide (clippy.toml); this module's
// call sites predate the policy and are tracked for burn-down in
// EXPERIMENTS.md — never-panic modules carry no such allow.
#![allow(clippy::disallowed_methods)]
use std::sync::Arc;

use crate::assoc::io::fmt_num;
use crate::assoc::kernel::{self, KernelConfig};
use crate::error::Result;
use crate::kvstore::{
    BatchWriter, IterConfig, RowRange, Table, WriterConfig,
};
use crate::metrics::Counter;

/// Minimum contracted-candidate rows per worker before a TableMult run
/// is sharded; below it the extra scans and writers cost more than the
/// parallelism returns.
const MIN_ROWS_PER_WORKER: usize = 8;

/// Tuning + instrumentation for a TableMult run.
pub struct TableMultOpts {
    pub writer: WriterConfig,
    /// Only contract row keys inside this range (supports sharded runs).
    pub row_range: RowRange,
    /// Treat every stored value as 1 (Graphulo's logical-AND multiply op —
    /// what the unweighted graph algorithms use).
    pub logical: bool,
    /// Pre-aggregate partial products in a bounded client buffer before
    /// writing (Graphulo's partial-sum combiner cache). `0` disables.
    /// Memory stays bounded: the buffer flushes to the store's summing
    /// combiner whenever it reaches this many distinct cells.
    pub combiner_cap: usize,
    /// Worker threads: `0` = the kernel pool's configured thread count.
    /// Each worker contracts a disjoint row-key shard with its own
    /// scans, combiner, and batch writer (same composition as running
    /// sharded `row_range`s sequentially — the store's summing combiner
    /// folds the shard contributions).
    pub workers: usize,
}

impl Default for TableMultOpts {
    fn default() -> Self {
        TableMultOpts {
            writer: WriterConfig::default(),
            row_range: RowRange::all(),
            logical: false,
            combiner_cap: 1 << 22,
            workers: 0,
        }
    }
}

/// Statistics returned by a TableMult run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TableMultStats {
    /// Row keys found in both A and B.
    pub rows_contracted: u64,
    /// Partial products emitted into C.
    pub partial_products: u64,
    /// Peak resident entries (max |row A| + |row B| held at once).
    pub peak_row_entries: usize,
}

/// Run `C += A^T * B` server-side, sharded across the kernel pool when
/// the operand is big enough. The contracted row-key set (a key-only
/// scan of A) is cut into `workers` contiguous shards at distinct key
/// boundaries — a row never straddles shards — and each worker runs the
/// streaming merge join over its own shard with its own writer; the
/// store's summing combiner folds the shard contributions, exactly as
/// the sequential sharded-`row_range` composition does.
pub fn table_mult(
    a: &Arc<Table>,
    b: &Arc<Table>,
    c: &Arc<Table>,
    opts: &TableMultOpts,
) -> Result<TableMultStats> {
    let threads = if opts.workers == 0 {
        KernelConfig::global().threads
    } else {
        opts.workers
    };
    let keys = a.scan_row_keys(&opts.row_range);
    let workers = threads.min(keys.len() / MIN_ROWS_PER_WORKER).max(1);
    if workers <= 1 {
        kernel::counters().serial_ops.inc();
        return table_mult_range(a, b, c, opts, &opts.row_range);
    }
    kernel::counters().parallel_ops.inc();
    // shard boundaries at distinct A-row keys, ends half-open like
    // RowRange itself; first/last shard inherit the caller's bounds
    let mut shards = Vec::with_capacity(workers);
    let mut start = opts.row_range.start.clone();
    for w in 1..=workers {
        let end = if w == workers {
            opts.row_range.end.clone()
        } else {
            Some(keys[keys.len() * w / workers].clone())
        };
        shards.push(RowRange { start: start.clone(), end: end.clone() });
        start = end;
    }
    let results: Vec<Result<TableMultStats>> = std::thread::scope(|s| {
        let handles: Vec<_> = shards
            .iter()
            .map(|r| s.spawn(move || table_mult_range(a, b, c, opts, r)))
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let mut stats = TableMultStats::default();
    for r in results {
        let s = r?;
        stats.rows_contracted += s.rows_contracted;
        stats.partial_products += s.partial_products;
        stats.peak_row_entries = stats.peak_row_entries.max(s.peak_row_entries);
    }
    Ok(stats)
}

/// One shard of a TableMult: the streaming merge join over `range`.
/// Memory stays bounded per worker: one row of A + one row of B + this
/// worker's write buffer.
fn table_mult_range(
    a: &Arc<Table>,
    b: &Arc<Table>,
    c: &Arc<Table>,
    opts: &TableMultOpts,
    range: &RowRange,
) -> Result<TableMultStats> {
    let cfg = IterConfig { summing: true, ..Default::default() };
    // Streaming snapshot scans of both operands in key order: only one
    // row of A and one row of B are ever resident — the operand tables
    // are never materialised, and no tablet lock is held while the
    // product loop runs, so concurrent writers proceed unimpeded.
    let mut sa = a.scan_stream(range, &cfg).peekable();
    let mut sb = b.scan_stream(range, &cfg).peekable();
    let mut writer = BatchWriter::new(c.clone(), opts.writer.clone());
    let products = Counter::new();
    let mut stats = TableMultStats::default();

    // row-at-a-time merge join on the row key. Column keys are interned
    // to u32 ids as rows stream by (one hash per entry), so the O(|rowA|
    // x |rowB|) product loop works on packed u64 cell ids instead of
    // string pairs — the §Perf fix that closes most of the gap to
    // client-side CSR (see EXPERIMENTS.md).
    let mut interner: std::collections::HashMap<String, u32> = std::collections::HashMap::new();
    let mut key_names: Vec<String> = Vec::new();
    let mut intern = |s: String, names: &mut Vec<String>| -> u32 {
        *interner.entry(s).or_insert_with_key(|k| {
            names.push(k.clone());
            (names.len() - 1) as u32
        })
    };
    let mut row_a: Vec<(u32, f64)> = Vec::new();
    let mut row_b: Vec<(u32, f64)> = Vec::new();
    // bounded partial-sum combiner (Graphulo's client-side combiner cache)
    let mut combiner: crate::util::FastMap<u64, f64> = crate::util::FastMap::default();
    loop {
        let (ka, kb) = match (sa.peek(), sb.peek()) {
            (Some(ea), Some(eb)) => (ea.key.row.clone(), eb.key.row.clone()),
            _ => break,
        };
        if ka < kb {
            // skip A rows with no B partner
            while sa.peek().map(|e| e.key.row == ka).unwrap_or(false) {
                sa.next();
            }
            continue;
        }
        if kb < ka {
            while sb.peek().map(|e| e.key.row == kb).unwrap_or(false) {
                sb.next();
            }
            continue;
        }
        // shared row k: buffer both rows (bounded by row degree)
        row_a.clear();
        row_b.clear();
        let parse = |v: &str| -> f64 {
            if opts.logical {
                1.0
            } else {
                v.parse().unwrap_or(0.0)
            }
        };
        while sa.peek().map(|e| e.key.row == ka).unwrap_or(false) {
            let e = sa.next().unwrap();
            let v = parse(&e.value);
            row_a.push((intern(e.key.cq, &mut key_names), v));
        }
        while sb.peek().map(|e| e.key.row == kb).unwrap_or(false) {
            let e = sb.next().unwrap();
            let v = parse(&e.value);
            row_b.push((intern(e.key.cq, &mut key_names), v));
        }
        stats.peak_row_entries = stats.peak_row_entries.max(row_a.len() + row_b.len());
        stats.rows_contracted += 1;
        for &(i, av) in &row_a {
            for &(j, bv) in &row_b {
                products.inc();
                if opts.combiner_cap == 0 {
                    writer.put(&key_names[i as usize], &key_names[j as usize], &fmt_num(av * bv))?;
                } else {
                    let cell = ((i as u64) << 32) | j as u64;
                    *combiner.entry(cell).or_insert(0.0) += av * bv;
                    if combiner.len() >= opts.combiner_cap {
                        flush_combiner(&mut combiner, &key_names, &mut writer)?;
                    }
                }
            }
        }
    }
    flush_combiner(&mut combiner, &key_names, &mut writer)?;
    writer.flush()?;
    stats.partial_products = products.get();
    Ok(stats)
}

/// Drain the partial-sum buffer into the batch writer.
fn flush_combiner(
    combiner: &mut crate::util::FastMap<u64, f64>,
    key_names: &[String],
    writer: &mut BatchWriter,
) -> Result<()> {
    for (cell, v) in combiner.drain() {
        if v != 0.0 {
            let i = (cell >> 32) as usize;
            let j = (cell & 0xFFFF_FFFF) as usize;
            writer.put(&key_names[i], &key_names[j], &fmt_num(v))?;
        }
    }
    Ok(())
}

/// Read the product table as an assoc (summing partial products).
pub fn read_product(c: &Arc<Table>) -> Result<crate::assoc::Assoc> {
    let cfg = IterConfig { summing: true, ..Default::default() };
    crate::connectors::accumulo::entries_to_assoc(c.scan_stream(&RowRange::all(), &cfg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assoc::Assoc;
    use crate::connectors::{AccumuloConnector, D4mTableConfig};
    use crate::kvstore::KvStore;

    fn setup(a: &Assoc, b: &Assoc) -> (Arc<KvStore>, Arc<Table>, Arc<Table>, Arc<Table>) {
        let store = Arc::new(KvStore::new());
        let acc = AccumuloConnector::with_store(store.clone());
        let cfg = D4mTableConfig { transpose: false, degrees: false, ..Default::default() };
        let ta = acc.bind("A", &cfg).unwrap();
        let tb = acc.bind("B", &cfg).unwrap();
        ta.put_assoc(a).unwrap();
        tb.put_assoc(b).unwrap();
        let tc = store.create_table("C", vec![]).unwrap();
        (store, ta.main(), tb.main(), tc)
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn matches_client_side_transpose_matmul() {
        let a = Assoc::from_triples(&[
            ("k1", "i1", 2.0),
            ("k1", "i2", 1.0),
            ("k2", "i1", 3.0),
        ]);
        let b = Assoc::from_triples(&[("k1", "j1", 4.0), ("k2", "j1", 1.0), ("k2", "j2", 5.0)]);
        let (_s, ta, tb, tc) = setup(&a, &b);
        let stats = table_mult(&ta, &tb, &tc, &TableMultOpts::default()).unwrap();
        let got = read_product(&tc).unwrap();
        let want = a.transpose().matmul(&b);
        assert_eq!(got.triples(), want.triples());
        assert_eq!(stats.rows_contracted, 2);
        assert_eq!(stats.partial_products, 2 + 2); // k1: 2x1, k2: 1x2
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn disjoint_rows_empty_product() {
        let a = Assoc::from_triples(&[("k1", "i", 1.0)]);
        let b = Assoc::from_triples(&[("k9", "j", 1.0)]);
        let (_s, ta, tb, tc) = setup(&a, &b);
        let stats = table_mult(&ta, &tb, &tc, &TableMultOpts::default()).unwrap();
        assert_eq!(stats.rows_contracted, 0);
        assert!(read_product(&tc).unwrap().is_empty());
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn accumulates_into_existing_product() {
        // two successive TableMults sum into C (the "+=" semantics)
        let a = Assoc::from_triples(&[("k", "i", 1.0)]);
        let b = Assoc::from_triples(&[("k", "j", 1.0)]);
        let (_s, ta, tb, tc) = setup(&a, &b);
        table_mult(&ta, &tb, &tc, &TableMultOpts::default()).unwrap();
        table_mult(&ta, &tb, &tc, &TableMultOpts::default()).unwrap();
        let got = read_product(&tc).unwrap();
        assert_eq!(got.get("i", "j"), 2.0);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn bounded_peak_memory() {
        // a power-law-ish table: one hub row, many leaf rows
        let mut t = vec![];
        for i in 0..50 {
            t.push((format!("hub"), format!("i{i:03}"), 1.0));
            t.push((format!("leaf{i:03}"), "i000".to_string(), 1.0));
        }
        let a = Assoc::from_triples(&t);
        let (_s, ta, tb, tc) = setup(&a, &a);
        let stats = table_mult(&ta, &tb, &tc, &TableMultOpts::default()).unwrap();
        // peak is the hub row (50 + 50), far below total entries (100+100)
        assert!(stats.peak_row_entries <= 100);
        // and the product matches the client computation
        let want = a.transpose().matmul(&a);
        assert_eq!(read_product(&tc).unwrap().triples(), want.triples());
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn parallel_workers_match_serial() {
        // ~60 contracted rows with integer-valued products, so the
        // shard sums are exact and serial/parallel must agree exactly
        let mut t = vec![];
        let mut rng = crate::util::XorShift64::new(42);
        for r in 0..60 {
            for c in 0..6 {
                if rng.chance(0.6) {
                    t.push((format!("k{r:03}"), format!("i{c}"), (rng.below(9) + 1) as f64));
                }
            }
        }
        let a = Assoc::from_triples(&t);
        let (_s1, ta1, tb1, tc1) = setup(&a, &a);
        let serial = table_mult(
            &ta1,
            &tb1,
            &tc1,
            &TableMultOpts { workers: 1, ..Default::default() },
        )
        .unwrap();
        let (_s2, ta2, tb2, tc2) = setup(&a, &a);
        let par = table_mult(
            &ta2,
            &tb2,
            &tc2,
            &TableMultOpts { workers: 4, ..Default::default() },
        )
        .unwrap();
        assert_eq!(read_product(&tc1).unwrap().triples(), read_product(&tc2).unwrap().triples());
        assert_eq!(serial.rows_contracted, par.rows_contracted);
        assert_eq!(serial.partial_products, par.partial_products);
        // a shard's peak can't exceed the serial run's
        assert!(par.peak_row_entries <= serial.peak_row_entries);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn parallel_respects_row_range_bounds() {
        // parallel sharding of a bounded range contracts the same rows
        let mut t = vec![];
        for r in 0..64 {
            t.push((format!("k{r:03}"), "i".to_string(), 1.0));
        }
        let a = Assoc::from_triples(&t);
        let (_s1, ta1, tb1, tc1) = setup(&a, &a);
        let range = RowRange::span("k010", "k050");
        let serial = table_mult(
            &ta1,
            &tb1,
            &tc1,
            &TableMultOpts { workers: 1, row_range: range.clone(), ..Default::default() },
        )
        .unwrap();
        let (_s2, ta2, tb2, tc2) = setup(&a, &a);
        let par = table_mult(
            &ta2,
            &tb2,
            &tc2,
            &TableMultOpts { workers: 4, row_range: range, ..Default::default() },
        )
        .unwrap();
        assert_eq!(serial.rows_contracted, 40);
        assert_eq!(par.rows_contracted, 40);
        assert_eq!(read_product(&tc1).unwrap().triples(), read_product(&tc2).unwrap().triples());
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn row_range_shards_compose() {
        // running two disjoint row-range shards == one full run
        let a = Assoc::from_triples(&[
            ("k1", "i", 1.0),
            ("k2", "i", 2.0),
            ("k3", "i", 3.0),
        ]);
        let b = Assoc::from_triples(&[("k1", "j", 1.0), ("k2", "j", 1.0), ("k3", "j", 1.0)]);
        let (_s, ta, tb, tc) = setup(&a, &b);
        let lo = TableMultOpts {
            row_range: RowRange::span("", "k2"),
            ..Default::default()
        };
        let hi = TableMultOpts { row_range: RowRange::from("k2"), ..Default::default() };
        table_mult(&ta, &tb, &tc, &lo).unwrap();
        table_mult(&ta, &tb, &tc, &hi).unwrap();
        let got = read_product(&tc).unwrap();
        assert_eq!(got.get("i", "j"), 6.0);
    }
}
