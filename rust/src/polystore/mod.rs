//! BigDAWG-style polystore (Elmore et al. 2015): multiple islands (one
//! per data model) with CAST between them. In BigDAWG, D4M served as the
//! **text island**; here all three islands are embedded engines and the
//! associative array is the interchange representation for every CAST —
//! exactly the paper's claim that "the D4M associative array model allows
//! for translation of data between Accumulo, SciDB and PostGRES".

use crate::assoc::Assoc;
use crate::connectors::{AccumuloConnector, D4mTableConfig, SciDbConnector, SqlConnector};
use crate::error::Result;

/// The island a named object lives in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Island {
    /// Key-value / text island (Accumulo engine; D4M's BigDAWG role).
    Text,
    /// Array island (SciDB engine).
    Array,
    /// Relational island (PostGRES/MySQL engine).
    Relational,
}

/// Default chunk size used when casting into the array island.
const DEFAULT_CHUNK: u64 = 256;

/// The polystore: one engine per island.
pub struct Polystore {
    pub text: AccumuloConnector,
    pub array: SciDbConnector,
    pub relational: SqlConnector,
}

impl Default for Polystore {
    fn default() -> Self {
        Self::new()
    }
}

impl Polystore {
    pub fn new() -> Self {
        Polystore {
            text: AccumuloConnector::new(),
            array: SciDbConnector::new(),
            relational: SqlConnector::new(),
        }
    }

    /// Store an assoc into an island under `name`.
    pub fn put(&self, island: Island, name: &str, a: &Assoc) -> Result<()> {
        match island {
            Island::Text => {
                let t = self.text.bind(name, &D4mTableConfig::default())?;
                t.put_assoc(a)
            }
            Island::Array => self.array.put_assoc(name, a, DEFAULT_CHUNK).map(|_| ()),
            Island::Relational => self.relational.put_assoc(name, a).map(|_| ()),
        }
    }

    /// Read an assoc from an island.
    pub fn get(&self, island: Island, name: &str) -> Result<Assoc> {
        match island {
            Island::Text => {
                let t = self.text.bind(name, &D4mTableConfig::default())?;
                t.get_assoc()
            }
            Island::Array => self.array.get_assoc(name),
            Island::Relational => self.relational.get_assoc(name),
        }
    }

    /// CAST an object between islands through the associative-array
    /// interchange form. Returns the casted assoc.
    pub fn cast(&self, from: Island, src: &str, to: Island, dst: &str) -> Result<Assoc> {
        let a = self.get(from, src)?;
        self.put(to, dst, &a)?;
        Ok(a)
    }

    /// A cross-island query plan: pull operands from (possibly different)
    /// islands, combine with an assoc op, store the result in a target
    /// island. The simplest BigDAWG-style scatter-gather.
    pub fn cross_join(
        &self,
        left: (Island, &str),
        right: (Island, &str),
        op: CrossOp,
        out: (Island, &str),
    ) -> Result<Assoc> {
        let a = self.get(left.0, left.1)?;
        let b = self.get(right.0, right.1)?;
        let c = match op {
            CrossOp::Add => a.add(&b),
            CrossOp::ElemMult => a.elem_mult(&b),
            CrossOp::MatMul => a.matmul(&b),
        };
        self.put(out.0, out.1, &c)?;
        Ok(c)
    }
}

/// Combining op for [`Polystore::cross_join`].
#[derive(Debug, Clone, Copy)]
pub enum CrossOp {
    Add,
    ElemMult,
    MatMul,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Assoc {
        Assoc::from_triples(&[("r1", "c1", 1.0), ("r1", "c2", 2.0), ("r2", "c1", 3.0)])
    }

    #[test]
    fn put_get_each_island() {
        let p = Polystore::new();
        let a = sample();
        for island in [Island::Text, Island::Array, Island::Relational] {
            p.put(island, "obj", &a).unwrap();
            let b = p.get(island, "obj").unwrap();
            assert_eq!(a.triples(), b.triples(), "{island:?}");
        }
    }

    #[test]
    fn cast_text_to_array_to_relational() {
        let p = Polystore::new();
        let a = sample();
        p.put(Island::Text, "t", &a).unwrap();
        p.cast(Island::Text, "t", Island::Array, "arr").unwrap();
        p.cast(Island::Array, "arr", Island::Relational, "rel").unwrap();
        let back = p.get(Island::Relational, "rel").unwrap();
        assert_eq!(a.triples(), back.triples());
    }

    #[test]
    fn cross_island_matmul() {
        let p = Polystore::new();
        let a = Assoc::from_triples(&[("r", "k", 2.0)]);
        let b = Assoc::from_triples(&[("k", "c", 3.0)]);
        p.put(Island::Array, "a", &a).unwrap();
        p.put(Island::Relational, "b", &b).unwrap();
        let c = p
            .cross_join((Island::Array, "a"), (Island::Relational, "b"), CrossOp::MatMul, (Island::Text, "c"))
            .unwrap();
        assert_eq!(c.get("r", "c"), 6.0);
        // and it landed in the text island
        assert_eq!(p.get(Island::Text, "c").unwrap().get("r", "c"), 6.0);
    }

    #[test]
    fn missing_object_errors() {
        let p = Polystore::new();
        assert!(p.get(Island::Array, "nope").is_err());
        assert!(p.get(Island::Relational, "nope").is_err());
    }
}
