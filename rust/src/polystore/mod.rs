//! BigDAWG-style polystore (Elmore et al. 2015): multiple islands (one
//! per data model) with CAST between them. In BigDAWG, D4M served as the
//! **text island**; here every island is any engine implementing the
//! unified [`DbServer`]/[`DbTable`] binding API, and the associative
//! array is the interchange representation for every CAST — exactly the
//! paper's claim that "the D4M associative array model allows for
//! translation of data between Accumulo, SciDB and PostGRES".
//!
//! The polystore itself is **engine-generic**: `put`/`get`/`query`/
//! `cast`/`cross_join` are pure trait calls with no per-engine dispatch.
//! Registering a fourth engine (or swapping an island's backend) is one
//! [`Polystore::register`] call with any `Box<dyn DbServer>`.

use crate::assoc::Assoc;
use crate::connectors::{
    AccumuloConnector, BindOpts, DbServer, DbTable, SciDbConnector, SqlConnector, TableQuery,
};
use crate::error::{D4mError, Result};

/// The island a named object lives in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Island {
    /// Key-value / text island (Accumulo engine; D4M's BigDAWG role).
    Text,
    /// Array island (SciDB engine).
    Array,
    /// Relational island (PostGRES/MySQL engine).
    Relational,
}

/// The polystore: one [`DbServer`] per island.
pub struct Polystore {
    islands: Vec<(Island, Box<dyn DbServer>)>,
}

impl Default for Polystore {
    fn default() -> Self {
        Self::new()
    }
}

impl Polystore {
    /// The default three-island configuration of the paper.
    pub fn new() -> Self {
        let mut p = Polystore { islands: Vec::new() };
        p.register(Island::Text, Box::new(AccumuloConnector::new()));
        p.register(Island::Array, Box::new(SciDbConnector::new()));
        p.register(Island::Relational, Box::new(SqlConnector::new()));
        p
    }

    /// An empty polystore; islands are added with [`Polystore::register`].
    pub fn with_no_islands() -> Self {
        Polystore { islands: Vec::new() }
    }

    /// Install (or replace) the engine behind an island. Connectors are
    /// cheaply clonable, so callers can keep a native handle to the same
    /// engine for engine-specific ops (e.g. SciDB in-store spgemm).
    pub fn register(&mut self, island: Island, server: Box<dyn DbServer>) {
        match self.islands.iter_mut().find(|(i, _)| *i == island) {
            Some(slot) => slot.1 = server,
            None => self.islands.push((island, server)),
        }
    }

    /// The engine behind an island.
    pub fn server(&self, island: Island) -> Result<&dyn DbServer> {
        self.islands
            .iter()
            .find(|(i, _)| *i == island)
            .map(|(_, s)| s.as_ref())
            .ok_or_else(|| D4mError::NotFound(format!("island {island:?} not registered")))
    }

    /// Bind a table in an island (the `T = DB('table')` call; eager
    /// engines create storage here).
    pub fn bind(&self, island: Island, name: &str) -> Result<Box<dyn DbTable>> {
        self.server(island)?.bind(name, &BindOpts::default())
    }

    /// Bind for reading: errors on a missing object instead of letting an
    /// eager engine create an empty table under a typo'd name.
    fn bound(&self, island: Island, name: &str) -> Result<Box<dyn DbTable>> {
        let server = self.server(island)?;
        if !server.exists(name) {
            return Err(D4mError::NotFound(format!("{name} in island {island:?}")));
        }
        server.bind(name, &BindOpts::default())
    }

    /// Store an assoc into an island under `name`.
    pub fn put(&self, island: Island, name: &str, a: &Assoc) -> Result<()> {
        self.bind(island, name)?.put_assoc(a)
    }

    /// Read an assoc from an island.
    pub fn get(&self, island: Island, name: &str) -> Result<Assoc> {
        self.bound(island, name)?.get_assoc()
    }

    /// The `T(r, c)` form against any island, selectors pushed down into
    /// whichever engine backs it.
    pub fn query(&self, island: Island, name: &str, q: &TableQuery) -> Result<Assoc> {
        self.bound(island, name)?.query(q)
    }

    /// CAST an object between islands through the associative-array
    /// interchange form. Returns the casted assoc.
    pub fn cast(&self, from: Island, src: &str, to: Island, dst: &str) -> Result<Assoc> {
        let a = self.get(from, src)?;
        self.put(to, dst, &a)?;
        Ok(a)
    }

    /// A cross-island query plan: pull operands from (possibly different)
    /// islands, combine with an assoc op, store the result in a target
    /// island. The simplest BigDAWG-style scatter-gather.
    pub fn cross_join(
        &self,
        left: (Island, &str),
        right: (Island, &str),
        op: CrossOp,
        out: (Island, &str),
    ) -> Result<Assoc> {
        let a = self.get(left.0, left.1)?;
        let b = self.get(right.0, right.1)?;
        let c = match op {
            CrossOp::Add => a.add(&b),
            CrossOp::ElemMult => a.elem_mult(&b),
            CrossOp::MatMul => a.matmul(&b),
        };
        self.put(out.0, out.1, &c)?;
        Ok(c)
    }
}

/// Combining op for [`Polystore::cross_join`].
#[derive(Debug, Clone, Copy)]
pub enum CrossOp {
    Add,
    ElemMult,
    MatMul,
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests panic by design
mod tests {
    use super::*;
    use crate::assoc::KeySel;

    fn sample() -> Assoc {
        Assoc::from_triples(&[("r1", "c1", 1.0), ("r1", "c2", 2.0), ("r2", "c1", 3.0)])
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn put_get_each_island() {
        let p = Polystore::new();
        let a = sample();
        for island in [Island::Text, Island::Array, Island::Relational] {
            p.put(island, "obj", &a).unwrap();
            let b = p.get(island, "obj").unwrap();
            assert_eq!(a.triples(), b.triples(), "{island:?}");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn cast_text_to_array_to_relational() {
        let p = Polystore::new();
        let a = sample();
        p.put(Island::Text, "t", &a).unwrap();
        p.cast(Island::Text, "t", Island::Array, "arr").unwrap();
        p.cast(Island::Array, "arr", Island::Relational, "rel").unwrap();
        let back = p.get(Island::Relational, "rel").unwrap();
        assert_eq!(a.triples(), back.triples());
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn cross_island_matmul() {
        let p = Polystore::new();
        let a = Assoc::from_triples(&[("r", "k", 2.0)]);
        let b = Assoc::from_triples(&[("k", "c", 3.0)]);
        p.put(Island::Array, "a", &a).unwrap();
        p.put(Island::Relational, "b", &b).unwrap();
        let c = p
            .cross_join((Island::Array, "a"), (Island::Relational, "b"), CrossOp::MatMul, (Island::Text, "c"))
            .unwrap();
        assert_eq!(c.get("r", "c"), 6.0);
        // and it landed in the text island
        assert_eq!(p.get(Island::Text, "c").unwrap().get("r", "c"), 6.0);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn island_query_pushdown() {
        let p = Polystore::new();
        let a = sample();
        let q = TableQuery::all().rows(KeySel::Range("r1".into(), "r1".into()));
        for island in [Island::Text, Island::Array, Island::Relational] {
            p.put(island, "q", &a).unwrap();
            let got = p.query(island, "q", &q).unwrap();
            assert_eq!(got.triples(), a.select_rows(&q.rows).triples(), "{island:?}");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn register_swaps_island_engine() {
        let mut p = Polystore::new();
        p.put(Island::Array, "obj", &sample()).unwrap();
        // swapping the backend drops the island's previous contents
        p.register(Island::Array, Box::new(SciDbConnector::new()));
        assert!(p.get(Island::Array, "obj").is_err());
        // ...and a shared-handle registration keeps native access
        let native = SqlConnector::new();
        p.register(Island::Array, Box::new(native.clone()));
        p.put(Island::Array, "obj", &sample()).unwrap();
        assert_eq!(native.get_assoc("obj").unwrap().triples(), sample().triples());
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn missing_object_errors() {
        let p = Polystore::new();
        // every island, including the eager key-value engine: a read of a
        // missing name errors and must not create the table
        assert!(p.get(Island::Text, "nope").is_err());
        assert!(p.get(Island::Array, "nope").is_err());
        assert!(p.get(Island::Relational, "nope").is_err());
        assert!(!p.server(Island::Text).unwrap().exists("nope"));
        assert!(Polystore::with_no_islands().get(Island::Text, "x").is_err());
    }
}
