//! Dense-block bridge: runs associative-array matrix multiplies through
//! the AOT-compiled Pallas kernels by tiling the aligned numeric matrices
//! into fixed-shape dense blocks (the artifact shapes), executing each
//! tile product on the PJRT engine, and accumulating.
//!
//! This is the "numeric hot path" of client-side D4M: for dense-ish
//! operands (e.g. co-occurrence matrices) it beats CSR SpGEMM; for very
//! sparse operands the CSR path wins. [`assoc_matmul_auto`] picks by a
//! density heuristic (tuned in the §Perf pass; see EXPERIMENTS.md).

use super::PjrtEngine;
use crate::assoc::spmat::SpMat;
use crate::assoc::Assoc;
use crate::error::Result;
use crate::util::intersect_sorted_keys;

/// Density above which the dense tile path is preferred (fraction of
/// nonzeros in the aligned operands).
pub const DENSE_THRESHOLD: f64 = 0.05;

/// Pick the artifact tile for a given problem shape: large tiles
/// amortise per-call PJRT overhead (literal copies, dispatch) once any
/// dimension exceeds half the large tile (§Perf: 507 calls -> 12 calls
/// on the e2e workload).
pub fn best_tile(k: usize, m: usize, n: usize) -> usize {
    if k.max(m).max(n) > super::TILE_LARGE / 2 {
        super::TILE_LARGE
    } else {
        super::TILE_SMALL
    }
}

/// Pad a CSR matrix into a row-major dense f32 buffer of shape
/// (rows_padded, cols_padded).
fn to_dense_padded(m: &SpMat, rows_padded: usize, cols_padded: usize) -> Vec<f32> {
    let mut out = vec![0f32; rows_padded * cols_padded];
    for r in 0..m.nr {
        for (c, v) in m.row(r) {
            out[r * cols_padded + c] = v as f32;
        }
    }
    out
}

/// Extract one (tile x tile) block starting at (r0, c0) from a padded
/// dense buffer with row stride `stride`.
fn block(buf: &[f32], stride: usize, r0: usize, c0: usize, tile: usize) -> Vec<f32> {
    let mut out = vec![0f32; tile * tile];
    for r in 0..tile {
        let src = (r0 + r) * stride + c0;
        out[r * tile..(r + 1) * tile].copy_from_slice(&buf[src..src + tile]);
    }
    out
}

fn div_up(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// `C = A^T B` over aligned CSR operands via dense tiles of edge `tile`
/// executed on the engine. a: (K, M), b: (K, N) -> (M, N) dense row-major
/// (trimmed to the true shape).
pub fn at_b_dense(
    engine: &PjrtEngine,
    a: &SpMat,
    b: &SpMat,
    tile: usize,
) -> Result<Vec<f64>> {
    assert_eq!(a.nr, b.nr, "contraction dim mismatch");
    let (k, m, n) = (a.nr, a.nc, b.nc);
    let (kp, mp, np) = (div_up(k, tile) * tile, div_up(m, tile) * tile, div_up(n, tile) * tile);
    let da = to_dense_padded(a, kp, mp);
    let db = to_dense_padded(b, kp, np);
    let mut out = vec![0f64; m * n];
    for bi in 0..mp / tile {
        for bj in 0..np / tile {
            // accumulate over the K tile axis
            let mut acc = vec![0f64; tile * tile];
            for bk in 0..kp / tile {
                let ta = block(&da, mp, bk * tile, bi * tile, tile);
                let tb = block(&db, np, bk * tile, bj * tile, tile);
                let tc = engine.tablemult_tile(&ta, &tb, tile)?;
                for (x, y) in acc.iter_mut().zip(tc.iter()) {
                    *x += *y as f64;
                }
            }
            // write back the valid region
            for r in 0..tile {
                let gr = bi * tile + r;
                if gr >= m {
                    break;
                }
                for c in 0..tile {
                    let gc = bj * tile + c;
                    if gc >= n {
                        break;
                    }
                    out[gr * n + gc] = acc[r * tile + c];
                }
            }
        }
    }
    Ok(out)
}

/// Key-aligned `A^T * B` over assocs routed through the dense tile path.
/// Alignment contracts over the intersection of row keys (TableMult form:
/// rows are the shared dimension).
pub fn assoc_at_b_dense(engine: &PjrtEngine, a: &Assoc, b: &Assoc, tile: usize) -> Result<Assoc> {
    let (_, ia, ib) = intersect_sorted_keys(a.row_keys(), b.row_keys());
    let cols_a: Vec<usize> = (0..a.col_keys().len()).collect();
    let cols_b: Vec<usize> = (0..b.col_keys().len()).collect();
    let sa = a.matrix().select(&ia, &cols_a);
    let sb = b.matrix().select(&ib, &cols_b);
    let dense = at_b_dense(engine, &sa, &sb, tile)?;
    let (m, n) = (sa.nc, sb.nc);
    let mut triples = Vec::new();
    for i in 0..m {
        for j in 0..n {
            let v = dense[i * n + j];
            if v != 0.0 {
                triples.push((a.col_keys()[i].clone(), b.col_keys()[j].clone(), v));
            }
        }
    }
    Ok(Assoc::from_triples(&triples))
}

/// Density of the aligned operands (used by the auto router).
pub fn aligned_density(a: &Assoc, b: &Assoc) -> f64 {
    let (inner, _, _) = intersect_sorted_keys(a.row_keys(), b.row_keys());
    let k = inner.len().max(1);
    let m = a.col_keys().len().max(1);
    let n = b.col_keys().len().max(1);
    let nnz = (a.nnz() + b.nnz()) as f64;
    nnz / ((k * m + k * n) as f64)
}

/// Route `A^T * B` to the dense PJRT path or the CSR path by density.
pub fn assoc_matmul_auto(
    engine: Option<&PjrtEngine>,
    a: &Assoc,
    b: &Assoc,
    tile: usize,
) -> Result<Assoc> {
    if let Some(e) = engine {
        if aligned_density(a, b) >= DENSE_THRESHOLD {
            let t = if tile == 0 {
                best_tile(a.row_keys().len(), a.col_keys().len(), b.col_keys().len())
            } else {
                tile
            };
            return assoc_at_b_dense(e, a, b, t);
        }
    }
    Ok(a.transpose().matmul(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Option<PjrtEngine> {
        PjrtEngine::new(PjrtEngine::default_dir()).ok()
    }

    fn dense_assoc(nr: usize, nc: usize, seed: u64) -> Assoc {
        let mut rng = crate::util::XorShift64::new(seed);
        let mut t = Vec::new();
        for r in 0..nr {
            for c in 0..nc {
                if rng.chance(0.5) {
                    t.push((format!("k{r:03}"), format!("c{c:03}"), (rng.below(5) + 1) as f64));
                }
            }
        }
        Assoc::from_triples(&t)
    }

    #[test]
    fn dense_path_matches_csr_small() {
        let Some(e) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let a = dense_assoc(40, 30, 1);
        let b = dense_assoc(40, 20, 2);
        let want = a.transpose().matmul(&b);
        let got = assoc_at_b_dense(&e, &a, &b, super::super::TILE_SMALL).unwrap();
        assert_eq!(want.triples().len(), got.triples().len());
        for (x, y) in want.triples().iter().zip(got.triples().iter()) {
            assert_eq!((&x.0, &x.1), (&y.0, &y.1));
            assert!((x.2 - y.2).abs() < 1e-3, "{x:?} vs {y:?}");
        }
    }

    #[test]
    fn dense_path_multi_tile() {
        let Some(e) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        // spans >1 tile in every dimension (tile = 128)
        let a = dense_assoc(150, 140, 3);
        let b = dense_assoc(150, 135, 4);
        let want = a.transpose().matmul(&b);
        let got = assoc_at_b_dense(&e, &a, &b, super::super::TILE_SMALL).unwrap();
        assert_eq!(want.nnz(), got.nnz());
        // spot check
        let wt = want.triples();
        for t in wt.iter().step_by(97) {
            assert!((got.get(&t.0, &t.1) - t.2).abs() < 1e-2);
        }
    }

    #[test]
    fn auto_router_falls_back_without_engine() {
        let a = dense_assoc(10, 10, 5);
        let b = dense_assoc(10, 10, 6);
        let got = assoc_matmul_auto(None, &a, &b, 128).unwrap();
        assert_eq!(got, a.transpose().matmul(&b));
    }

    #[test]
    fn density_estimate_sane() {
        let a = dense_assoc(20, 20, 7);
        let d = aligned_density(&a, &a);
        assert!(d > 0.2 && d <= 1.0, "density {d}");
    }
}
