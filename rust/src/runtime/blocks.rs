//! Dense-block kernels: the in-crate cache-blocked f64 GEMM and the
//! bridge that runs associative-array matrix multiplies through it by
//! aligning the operands, densifying, and tiling.
//!
//! This is the "numeric hot path" of client-side D4M: for dense-ish
//! operands (e.g. co-occurrence matrices) it beats CSR SpGEMM; for very
//! sparse operands the CSR path wins. [`assoc_matmul_auto`] picks by a
//! density heuristic (tuned in the §Perf pass; see EXPERIMENTS.md).
//!
//! Determinism: [`gemm`] always walks k-tiles in ascending order in the
//! outermost loop, so every output cell accumulates its k-terms in the
//! same order regardless of tile size or worker count — results are
//! bit-identical across configurations, mirroring the SpGEMM guarantee.

use super::DenseEngine;
use crate::assoc::kernel::{self, KernelConfig};
use crate::assoc::spmat::SpMat;
use crate::assoc::Assoc;
use crate::error::Result;
use crate::util::intersect_sorted_keys;

/// Density above which the dense tile path is preferred (fraction of
/// nonzeros in the aligned operands).
pub const DENSE_THRESHOLD: f64 = 0.05;

/// Pick the tile edge for a given problem shape: large tiles amortise
/// loop overhead once any dimension exceeds half the large tile, small
/// tiles keep tiny problems from padding work.
pub fn best_tile(k: usize, m: usize, n: usize) -> usize {
    if k.max(m).max(n) > super::TILE_LARGE / 2 {
        super::TILE_LARGE
    } else {
        super::TILE_SMALL
    }
}

fn div_up(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// Blocked dense `C = A B`: a is (m, k), b is (k, n), both row-major
/// f64; returns (m, n) row-major. Tiled over all three dimensions so the
/// working set (one A row strip, one B tile) stays cache-resident, and
/// parallel over contiguous row blocks via `std::thread::scope` when the
/// FLOP estimate clears `cfg.parallel_cutoff`. The k-tile loop is
/// outermost and ascending, so each `c[i][j]` sees its additions in a
/// fixed order — bit-identical output for every tile size/thread count.
pub fn gemm(
    a: &[f64],
    b: &[f64],
    m: usize,
    k: usize,
    n: usize,
    tile: usize,
    cfg: &KernelConfig,
) -> Vec<f64> {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(b.len(), k * n, "B shape");
    let mut c = vec![0f64; m * n];
    if m == 0 || n == 0 || k == 0 {
        return c;
    }
    let tile = tile.max(8);
    let flops = (m as u64).saturating_mul(k as u64).saturating_mul(n as u64);
    let workers = kernel::plan_workers(cfg, flops).min(div_up(m, tile)).max(1);

    // Dense work is uniform per row, so contiguous equal row-tile groups
    // balance; split at tile boundaries so no output row is shared.
    let row_tiles = div_up(m, tile);
    let run = |rows: std::ops::Range<usize>, c: &mut [f64]| {
        let r0 = rows.start;
        for kt in (0..k).step_by(tile) {
            let kend = (kt + tile).min(k);
            for jt in (0..n).step_by(tile) {
                let jend = (jt + tile).min(n);
                for i in rows.clone() {
                    let arow = &a[i * k..i * k + k];
                    let crow = &mut c[(i - r0) * n..(i - r0) * n + n];
                    for kx in kt..kend {
                        let av = arow[kx];
                        if av == 0.0 {
                            continue;
                        }
                        let brow = &b[kx * n..kx * n + n];
                        for jx in jt..jend {
                            crow[jx] += av * brow[jx];
                        }
                    }
                }
            }
        }
    };

    if workers <= 1 {
        run(0..m, &mut c);
    } else {
        let mut chunks: Vec<&mut [f64]> = Vec::with_capacity(workers);
        let mut bounds = Vec::with_capacity(workers + 1);
        let mut rest = c.as_mut_slice();
        let mut row = 0usize;
        for w in 0..workers {
            let end_tile = row_tiles * (w + 1) / workers;
            let end_row = (end_tile * tile).min(m);
            let (head, tail) = rest.split_at_mut((end_row - row) * n);
            chunks.push(head);
            rest = tail;
            bounds.push(row..end_row);
            row = end_row;
        }
        let run = &run;
        std::thread::scope(|s| {
            for (rows, chunk) in bounds.into_iter().zip(chunks) {
                s.spawn(move || run(rows, chunk));
            }
        });
    }
    c
}

/// `C = A^T B` over aligned CSR operands via the dense blocked GEMM.
/// a: (K, M), b: (K, N) -> (M, N) dense row-major.
pub fn at_b_dense(engine: &DenseEngine, a: &SpMat, b: &SpMat, tile: usize) -> Result<Vec<f64>> {
    assert_eq!(a.nr, b.nr, "contraction dim mismatch");
    let (k, m, n) = (a.nr, a.nc, b.nc);
    // densify A transposed (M, K) and B as-is (K, N)
    let mut at = vec![0f64; m * k];
    for r in 0..k {
        for (c, v) in a.row(r) {
            at[c * k + r] = v;
        }
    }
    let mut db = vec![0f64; k * n];
    for r in 0..k {
        for (c, v) in b.row(r) {
            db[r * n + c] = v;
        }
    }
    engine.calls.inc();
    Ok(gemm(&at, &db, m, k, n, tile, engine.config()))
}

/// Key-aligned `A^T * B` over assocs routed through the dense tile path.
/// Alignment contracts over the intersection of row keys (TableMult form:
/// rows are the shared dimension).
pub fn assoc_at_b_dense(engine: &DenseEngine, a: &Assoc, b: &Assoc, tile: usize) -> Result<Assoc> {
    let (_, ia, ib) = intersect_sorted_keys(a.row_keys(), b.row_keys());
    let cols_a: Vec<usize> = (0..a.col_keys().len()).collect();
    let cols_b: Vec<usize> = (0..b.col_keys().len()).collect();
    let sa = a.matrix().select(&ia, &cols_a);
    let sb = b.matrix().select(&ib, &cols_b);
    let dense = at_b_dense(engine, &sa, &sb, tile)?;
    let (m, n) = (sa.nc, sb.nc);
    let mut triples = Vec::new();
    for i in 0..m {
        for j in 0..n {
            let v = dense[i * n + j];
            if v != 0.0 {
                triples.push((a.col_keys()[i].clone(), b.col_keys()[j].clone(), v));
            }
        }
    }
    Ok(Assoc::from_triples(&triples))
}

/// Density of the aligned operands (used by the auto router).
pub fn aligned_density(a: &Assoc, b: &Assoc) -> f64 {
    let (inner, _, _) = intersect_sorted_keys(a.row_keys(), b.row_keys());
    let k = inner.len().max(1);
    let m = a.col_keys().len().max(1);
    let n = b.col_keys().len().max(1);
    let nnz = (a.nnz() + b.nnz()) as f64;
    nnz / ((k * m + k * n) as f64)
}

/// Route `A^T * B` to the dense blocked-GEMM path or the CSR path by
/// density.
pub fn assoc_matmul_auto(
    engine: Option<&DenseEngine>,
    a: &Assoc,
    b: &Assoc,
    tile: usize,
) -> Result<Assoc> {
    if let Some(e) = engine {
        if aligned_density(a, b) >= DENSE_THRESHOLD {
            let t = if tile == 0 {
                best_tile(a.row_keys().len(), a.col_keys().len(), b.col_keys().len())
            } else {
                tile
            };
            return assoc_at_b_dense(e, a, b, t);
        }
    }
    Ok(a.transpose().matmul(b))
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests panic by design
mod tests {
    use super::*;

    fn engine() -> DenseEngine {
        DenseEngine::with_config(KernelConfig {
            threads: 4,
            parallel_cutoff: 0,
            ..KernelConfig::global()
        })
    }

    fn dense_assoc(nr: usize, nc: usize, seed: u64) -> Assoc {
        let mut rng = crate::util::XorShift64::new(seed);
        let mut t = Vec::new();
        for r in 0..nr {
            for c in 0..nc {
                if rng.chance(0.5) {
                    t.push((format!("k{r:03}"), format!("c{c:03}"), (rng.below(5) + 1) as f64));
                }
            }
        }
        Assoc::from_triples(&t)
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn dense_path_matches_csr_small() {
        let e = engine();
        let a = dense_assoc(40, 30, 1);
        let b = dense_assoc(40, 20, 2);
        let want = a.transpose().matmul(&b);
        let got = assoc_at_b_dense(&e, &a, &b, super::super::TILE_SMALL).unwrap();
        assert_eq!(want.triples().len(), got.triples().len());
        for (x, y) in want.triples().iter().zip(got.triples().iter()) {
            assert_eq!((&x.0, &x.1), (&y.0, &y.1));
            assert!((x.2 - y.2).abs() < 1e-9, "{x:?} vs {y:?}");
        }
        assert!(e.calls.get() >= 1);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn dense_path_multi_tile() {
        let e = engine();
        // spans >1 tile in every dimension (tile = 128)
        let a = dense_assoc(150, 140, 3);
        let b = dense_assoc(150, 135, 4);
        let want = a.transpose().matmul(&b);
        let got = assoc_at_b_dense(&e, &a, &b, super::super::TILE_SMALL).unwrap();
        assert_eq!(want.nnz(), got.nnz());
        let wt = want.triples();
        for t in wt.iter().step_by(97) {
            assert!((got.get(&t.0, &t.1) - t.2).abs() < 1e-9);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn gemm_bit_identical_across_tiles_and_threads() {
        let (m, k, n) = (45, 45, 45);
        let mut rng = crate::util::XorShift64::new(11);
        let a: Vec<f64> = (0..m * k).map(|_| (rng.below(1000) as f64) / 7.0 - 60.0).collect();
        let b: Vec<f64> = (0..k * n).map(|_| (rng.below(1000) as f64) / 11.0 - 40.0).collect();
        let base = gemm(&a, &b, m, k, n, 16, &KernelConfig::serial());
        for (tile, threads) in [(16, 2), (16, 8), (8, 4), (64, 3)] {
            let cfg = KernelConfig {
                threads,
                parallel_cutoff: 0,
                ..KernelConfig::global()
            };
            let got = gemm(&a, &b, m, k, n, tile, &cfg);
            assert!(
                base.iter().zip(got.iter()).all(|(x, y)| x.to_bits() == y.to_bits()),
                "tile={tile} threads={threads} not bit-identical"
            );
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn auto_router_falls_back_without_engine() {
        let a = dense_assoc(10, 10, 5);
        let b = dense_assoc(10, 10, 6);
        let got = assoc_matmul_auto(None, &a, &b, 128).unwrap();
        assert_eq!(got, a.transpose().matmul(&b));
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn density_estimate_sane() {
        let a = dense_assoc(20, 20, 7);
        let d = aligned_density(&a, &a);
        assert!(d > 0.2 && d <= 1.0, "density {d}");
    }
}
