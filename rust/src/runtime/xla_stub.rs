//! Offline stub for the `xla` PJRT bindings.
//!
//! The dense-tile hot path was written against the vendored `xla` crate
//! (PJRT CPU client + AOT-compiled HLO artifacts). That crate is not
//! available in the offline/CI build — and the crate's dependency list
//! is intentionally empty — so this stub satisfies the same API surface
//! and reports "unavailable" at client construction:
//! [`PjRtClient::cpu`] always errors, [`PjrtEngine::new`] therefore
//! fails cleanly, and every dense caller takes its documented
//! degradation path (`with_engine(None)` → CSR kernels). Swapping the
//! real bindings back in is deleting this file and restoring the
//! dependency; no call site changes.
//!
//! Everything past `cpu()` is unreachable in stub builds but must
//! type-check, so each method returns the same "unavailable" error
//! rather than panicking.
//!
//! [`PjrtEngine::new`]: super::PjrtEngine::new
//! [`PjRtClient::cpu`]: PjRtClient::cpu

use std::fmt;

/// Error type mirroring the binding crate's: anything `Display`able
/// satisfies the `rt_err` wrapper in `runtime`.
#[derive(Debug, Clone)]
pub struct XlaError(&'static str);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable<T>() -> Result<T, XlaError> {
    Err(XlaError("PJRT runtime unavailable: built with the offline xla stub"))
}

/// Stub PJRT client; `cpu()` always fails so no engine is constructed.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self, XlaError> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable()
    }
}

/// Stub HLO module proto.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, XlaError> {
        unavailable()
    }
}

/// Stub XLA computation.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Stub loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable()
    }
}

/// Stub device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable()
    }
}

/// Stub host literal.
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Self {
        Literal
    }

    pub fn reshape(&self, _shape: &[i64]) -> Result<Literal, XlaError> {
        unavailable()
    }

    pub fn to_tuple1(&self) -> Result<Literal, XlaError> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        unavailable()
    }
}
