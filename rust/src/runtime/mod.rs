//! Native dense runtime — in-crate blocked dense-GEMM kernels behind the
//! engine facade that used to front the PJRT/XLA stub.
//!
//! History: the dense path was originally written against vendored `xla`
//! PJRT bindings executing AOT-compiled JAX/Pallas artifacts; the
//! offline/CI build had no such crate, so a stub made engine
//! construction fail and every dense caller silently degraded to CSR.
//! The stub is gone: [`DenseEngine`] is always constructible and executes
//! a cache-blocked f64 GEMM in-crate ([`blocks::gemm`]), parallel over
//! row tiles through the assoc kernel pool — the dense fallback is real
//! code with real tests, not an error path.

pub mod blocks;

use crate::assoc::kernel::KernelConfig;
use crate::metrics::Counter;

/// Small tile edge (test/default config).
pub const TILE_SMALL: usize = 128;
/// Large tile edge (production config).
pub const TILE_LARGE: usize = 512;

/// Dense kernel engine: tiled f64 kernels executed natively. Carries the
/// execution counter (for EXPERIMENTS.md §Perf accounting) and pins the
/// kernel configuration its GEMMs run under.
pub struct DenseEngine {
    cfg: KernelConfig,
    /// Kernel executions performed.
    pub calls: Counter,
}

impl DenseEngine {
    /// Engine under the process-wide [`KernelConfig`].
    pub fn new() -> Self {
        DenseEngine::with_config(KernelConfig::global())
    }

    /// Engine under an explicit kernel configuration.
    pub fn with_config(cfg: KernelConfig) -> Self {
        DenseEngine { cfg, calls: Counter::new() }
    }

    pub fn platform(&self) -> String {
        "native-blocked".to_string()
    }

    /// The kernel configuration this engine's GEMMs run under.
    pub fn config(&self) -> &KernelConfig {
        &self.cfg
    }

    /// `C = A B` on dense row-major f64 buffers: a is (m, k), b is
    /// (k, n); returns (m, n) row-major.
    pub fn matmul(&self, a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
        self.calls.inc();
        blocks::gemm(a, b, m, k, n, blocks::best_tile(k, m, n), &self.cfg)
    }

    /// `C = A^T B` on dense row-major f64 buffers: a is (k, m), b is
    /// (k, n); returns (m, n) row-major. Transposes A once, then runs the
    /// row-major blocked GEMM (unit-stride inner loops on both operands).
    pub fn at_b(&self, a: &[f64], b: &[f64], k: usize, m: usize, n: usize) -> Vec<f64> {
        let mut at = vec![0f64; m * k];
        for r in 0..k {
            for c in 0..m {
                at[c * k + r] = a[r * m + c];
            }
        }
        self.matmul(&at, b, m, k, n)
    }

    // ----------------------------------------------- square-tile wrappers
    // (the artifact-shaped entry points the PJRT path exposed; kept so
    // tile-level callers and tests keep working on the native engine)

    /// `C = A^T B` on one dense square tile: a and b are (tile, tile).
    pub fn tablemult_tile(&self, a: &[f64], b: &[f64], tile: usize) -> Vec<f64> {
        self.at_b(a, b, tile, tile, tile)
    }

    /// `C = A B` on one dense square tile.
    pub fn matmul_tile(&self, a: &[f64], b: &[f64], tile: usize) -> Vec<f64> {
        self.matmul(a, b, tile, tile, tile)
    }

    /// Row sums of a (tile, tile) block -> length `tile`.
    pub fn degree_tile(&self, a: &[f64], tile: usize) -> Vec<f64> {
        self.calls.inc();
        (0..tile).map(|r| a[r * tile..(r + 1) * tile].iter().sum()).collect()
    }

    /// Fused Jaccard over a 0/1 incidence tile a (tile, tile): returns
    /// the (tile, tile) coefficient matrix
    /// `J[i][j] = |i ∩ j| / (|i| + |j| - |i ∩ j|)` over column supports.
    pub fn jaccard_tile(&self, a: &[f64], tile: usize) -> Vec<f64> {
        let inter = self.at_b(a, a, tile, tile, tile);
        let mut deg = vec![0f64; tile];
        for r in 0..tile {
            for c in 0..tile {
                deg[c] += a[r * tile + c];
            }
        }
        let mut out = vec![0f64; tile * tile];
        for i in 0..tile {
            for j in 0..tile {
                let x = inter[i * tile + j];
                let denom = deg[i] + deg[j] - x;
                if denom > 0.0 {
                    out[i * tile + j] = x / denom;
                }
            }
        }
        out
    }
}

impl Default for DenseEngine {
    fn default() -> Self {
        DenseEngine::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> DenseEngine {
        // pinned multi-thread config so the parallel row-tile path is
        // exercised regardless of the host's core count
        DenseEngine::with_config(KernelConfig {
            threads: 4,
            parallel_cutoff: 0,
            ..KernelConfig::global()
        })
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn tablemult_tile_identity() {
        let e = engine();
        let t = TILE_SMALL;
        // a = I (so a^T b = b), b = counter pattern
        let mut a = vec![0f64; t * t];
        for i in 0..t {
            a[i * t + i] = 1.0;
        }
        let b: Vec<f64> = (0..t * t).map(|i| (i % 7) as f64).collect();
        let c = e.tablemult_tile(&a, &b, t);
        assert_eq!(c, b);
        assert_eq!(e.calls.get(), 1);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn matmul_tile_matches_scalar() {
        let e = engine();
        let t = TILE_SMALL;
        let a: Vec<f64> = (0..t * t).map(|i| ((i % 5) as f64) - 2.0).collect();
        let b: Vec<f64> = (0..t * t).map(|i| ((i % 3) as f64) - 1.0).collect();
        let c = e.matmul_tile(&a, &b, t);
        for &(i, j) in &[(0usize, 0usize), (17, 93), (127, 127)] {
            let want: f64 = (0..t).map(|k| a[i * t + k] * b[k * t + j]).sum();
            assert!((c[i * t + j] - want).abs() < 1e-9, "({i},{j})");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn rectangular_matmul_matches_scalar() {
        let (m, k, n) = (37, 21, 53); // deliberately not tile multiples
        let a: Vec<f64> = (0..m * k).map(|i| ((i % 11) as f64) / 3.0 - 1.5).collect();
        let b: Vec<f64> = (0..k * n).map(|i| ((i % 7) as f64) / 2.0 - 1.0).collect();
        let c = DenseEngine::new().matmul(&a, &b, m, k, n);
        for i in (0..m).step_by(9) {
            for j in (0..n).step_by(13) {
                let want: f64 = (0..k).map(|x| a[i * k + x] * b[x * n + j]).sum();
                assert!((c[i * n + j] - want).abs() < 1e-9, "({i},{j})");
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn degree_tile_rowsums() {
        let e = engine();
        let t = TILE_SMALL;
        let a = vec![1f64; t * t];
        let d = e.degree_tile(&a, t);
        assert_eq!(d.len(), t);
        assert!(d.iter().all(|&x| (x - t as f64).abs() < 1e-9));
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn jaccard_tile_diagonal_ones() {
        let e = engine();
        let t = TILE_SMALL;
        // deterministic 0/1 incidence with every column nonempty
        let mut a = vec![0f64; t * t];
        for i in 0..t {
            for j in 0..t {
                if (i * 31 + j * 17) % 5 == 0 {
                    a[i * t + j] = 1.0;
                }
            }
            a[i * t + i] = 1.0;
        }
        let jm = e.jaccard_tile(&a, t);
        for j in 0..t {
            assert!((jm[j * t + j] - 1.0).abs() < 1e-9, "diag {j} = {}", jm[j * t + j]);
        }
    }
}
