//! PJRT runtime — loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts`) and executes
//! them from the L3 hot path. Python never runs here.
//!
//! Interchange is HLO *text* (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that the crate's XLA (0.5.1) rejects, while the
//! text parser reassigns ids cleanly — see DESIGN.md and aot.py.

pub mod blocks;

// The dense path was written against the vendored `xla` PJRT bindings;
// the offline/CI build has no such crate, so a std-only stub satisfies
// the same API and fails at client construction — `PjrtEngine::new`
// errors cleanly and every dense caller degrades to the CSR path. See
// xla_stub.rs for the swap-back story.
#[path = "xla_stub.rs"]
mod xla;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::error::{D4mError, Result};

/// Small tile edge (test/default config).
pub const TILE_SMALL: usize = 128;
/// Large tile edge (production config).
pub const TILE_LARGE: usize = 512;

fn rt_err<E: std::fmt::Display>(e: E) -> D4mError {
    D4mError::Runtime(e.to_string())
}

/// A compiled-executable cache over a PJRT CPU client.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    dir: PathBuf,
    execs: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
    /// Executions performed (for EXPERIMENTS.md §Perf accounting).
    pub calls: crate::metrics::Counter,
}

impl PjrtEngine {
    /// Create an engine over the artifacts directory. Fails fast if the
    /// directory does not exist (run `make artifacts`).
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        if !dir.is_dir() {
            return Err(D4mError::Runtime(format!(
                "artifacts directory {} missing — run `make artifacts`",
                dir.display()
            )));
        }
        let client = xla::PjRtClient::cpu().map_err(rt_err)?;
        Ok(PjrtEngine {
            client,
            dir,
            execs: Mutex::new(HashMap::new()),
            calls: crate::metrics::Counter::new(),
        })
    }

    /// Resolve the conventional artifacts dir (`$D4M_ARTIFACTS` or
    /// `./artifacts`).
    pub fn default_dir() -> PathBuf {
        std::env::var("D4M_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| "artifacts".into())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the named artifact.
    fn load(&self, name: &str) -> Result<()> {
        let mut execs = self.execs.lock().unwrap();
        if execs.contains_key(name) {
            return Ok(());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        if !path.is_file() {
            return Err(D4mError::Runtime(format!("artifact {} missing", path.display())));
        }
        let proto =
            xla::HloModuleProto::from_text_file(path.to_str().unwrap()).map_err(rt_err)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(rt_err)?;
        execs.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute a named artifact on f32 inputs with the given shapes;
    /// returns the flattened f32 output (the lowered graphs return a
    /// 1-tuple, unwrapped here).
    pub fn run_f32(&self, name: &str, inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
        self.load(name)?;
        let execs = self.execs.lock().unwrap();
        let exe = execs.get(name).unwrap();
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, shape)| xla::Literal::vec1(data).reshape(shape).map_err(rt_err))
            .collect::<Result<Vec<_>>>()?;
        let result = exe.execute::<xla::Literal>(&literals).map_err(rt_err)?[0][0]
            .to_literal_sync()
            .map_err(rt_err)?;
        self.calls.inc();
        let out = result.to_tuple1().map_err(rt_err)?;
        out.to_vec::<f32>().map_err(rt_err)
    }

    // -------------------------------------------------------- wrappers

    /// `C = A^T B` on one dense tile: a is (k, m), b is (k, n) with
    /// k = m = n = `tile` (128 or 512); returns (m, n) row-major.
    pub fn tablemult_tile(&self, a: &[f32], b: &[f32], tile: usize) -> Result<Vec<f32>> {
        let name = format!("tablemult_{tile}x{tile}x{tile}");
        let t = tile as i64;
        self.run_f32(&name, &[(a, &[t, t]), (b, &[t, t])])
    }

    /// `C = A B` on one dense tile (m, k) x (k, n), square `tile`.
    pub fn matmul_tile(&self, a: &[f32], b: &[f32], tile: usize) -> Result<Vec<f32>> {
        let name = format!("matmul_{tile}x{tile}x{tile}");
        let t = tile as i64;
        self.run_f32(&name, &[(a, &[t, t]), (b, &[t, t])])
    }

    /// Row sums of a (tile, tile) block -> (tile, 1).
    pub fn degree_tile(&self, a: &[f32], tile: usize) -> Result<Vec<f32>> {
        let name = format!("degree_{tile}x{tile}");
        let t = tile as i64;
        self.run_f32(&name, &[(a, &[t, t])])
    }

    /// Fused Jaccard over an incidence tile a (tile, tile): returns the
    /// (tile, tile) coefficient matrix.
    pub fn jaccard_tile(&self, a: &[f32], tile: usize) -> Result<Vec<f32>> {
        let name = format!("jaccard_{tile}x{tile}");
        let t = tile as i64;
        self.run_f32(&name, &[(a, &[t, t])])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Option<PjrtEngine> {
        PjrtEngine::new(PjrtEngine::default_dir()).ok()
    }

    #[test]
    fn missing_dir_errors() {
        assert!(PjrtEngine::new("/nonexistent/artifacts").is_err());
    }

    #[test]
    fn tablemult_tile_identity() {
        let Some(e) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let t = TILE_SMALL;
        // a = I (so a^T b = b), b = counter pattern
        let mut a = vec![0f32; t * t];
        for i in 0..t {
            a[i * t + i] = 1.0;
        }
        let b: Vec<f32> = (0..t * t).map(|i| (i % 7) as f32).collect();
        let c = e.tablemult_tile(&a, &b, t).unwrap();
        assert_eq!(c, b);
        assert_eq!(e.calls.get(), 1);
    }

    #[test]
    fn matmul_tile_matches_cpu() {
        let Some(e) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let t = TILE_SMALL;
        let a: Vec<f32> = (0..t * t).map(|i| ((i % 5) as f32) - 2.0).collect();
        let b: Vec<f32> = (0..t * t).map(|i| ((i % 3) as f32) - 1.0).collect();
        let c = e.matmul_tile(&a, &b, t).unwrap();
        // spot-check a few cells against scalar compute
        for &(i, j) in &[(0usize, 0usize), (17, 93), (127, 127)] {
            let want: f32 = (0..t).map(|k| a[i * t + k] * b[k * t + j]).sum();
            assert!((c[i * t + j] - want).abs() < 1e-2, "({i},{j})");
        }
    }

    #[test]
    fn degree_tile_rowsums() {
        let Some(e) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let t = TILE_SMALL;
        let a = vec![1f32; t * t];
        let d = e.degree_tile(&a, t).unwrap();
        assert_eq!(d.len(), t);
        assert!(d.iter().all(|&x| (x - t as f32).abs() < 1e-3));
    }

    #[test]
    fn jaccard_tile_diagonal_ones() {
        let Some(e) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let t = TILE_SMALL;
        // deterministic 0/1 incidence with every column nonempty
        let mut a = vec![0f32; t * t];
        for i in 0..t {
            for j in 0..t {
                if (i * 31 + j * 17) % 5 == 0 {
                    a[i * t + j] = 1.0;
                }
            }
            a[i * t + i] = 1.0;
        }
        let jm = e.jaccard_tile(&a, t).unwrap();
        for j in 0..t {
            assert!((jm[j * t + j] - 1.0).abs() < 1e-4, "diag {j} = {}", jm[j * t + j]);
        }
    }
}
