//! The unified D4M binding surface — the paper's `DB()` / `T = DB('table')`
//! / `T(r, c)` API as two object-safe traits every engine implements.
//!
//! * [`DbServer`] is the `DBserver`/`dbsetup` surface: list tables,
//!   existence checks, deletion, and `bind(name, &BindOpts)` which hands
//!   back a [`DbTable`] trait object.
//! * [`DbTable`] is the `DBtable` surface: `put_assoc` / `get_assoc` /
//!   `nnz`, plus [`DbTable::query`] — the `T(r, c)` form, carried by a
//!   [`TableQuery`] builder whose row/col [`KeySel`] selectors are pushed
//!   down into each engine (Accumulo row-range and transpose scans, SciDB
//!   `subarray` windows, SQL `WHERE` predicates) — and [`DbTable::scan`],
//!   a paged iterator ([`AssocPages`]) for larger-than-memory reads, the
//!   D4M.jl table-iterator pattern.
//!
//! The contract that makes cross-engine code possible: for the same stored
//! associative array and the same `TableQuery`, **every engine returns an
//! identical [`Assoc`]** (`connectors::tests::conformance_*` enforce this).
//! Engines push selectors down as a *superset* scan, then normalise with
//! the exact client-side subsref, so pushdown is an optimisation, never a
//! semantics change.
//!
//! Registering a fourth engine is one `impl DbServer` + one `impl
//! DbTable`; `Polystore` and the coordinator only ever see the traits.
//! See DESIGN.md §Connectors for the paper-to-module mapping.

// unwrap/expect are disallowed repo-wide (clippy.toml); this module's
// call sites predate the policy and are tracked for burn-down in
// EXPERIMENTS.md — never-panic modules carry no such allow.
#![allow(clippy::disallowed_methods)]
use crate::assoc::{Assoc, KeySel};
use crate::error::Result;

use super::DbKind;

/// Engine-agnostic options for binding a table (the knobs of the MATLAB
/// `DB('table')` call). Engines ignore what they cannot use: `splits`,
/// `transpose` and `degrees` drive the Accumulo D4M-2.0 schema; `chunk`
/// drives SciDB chunking; SQL needs none of them.
#[derive(Debug, Clone)]
pub struct BindOpts {
    /// Maintain a transpose table (Accumulo; enables column pushdown).
    pub transpose: bool,
    /// Maintain a degree table (Accumulo).
    pub degrees: bool,
    /// Split points for the row keyspace (Accumulo).
    pub splits: Vec<String>,
    /// Split points for the column keyspace (Accumulo).
    pub transpose_splits: Vec<String>,
    /// Chunk size for array engines (SciDB).
    pub chunk: u64,
}

impl Default for BindOpts {
    fn default() -> Self {
        BindOpts {
            transpose: true,
            degrees: true,
            splits: vec![],
            transpose_splits: vec![],
            chunk: 256,
        }
    }
}

/// The `T(r, c)` query form as a builder: row/col key selectors, an
/// optional result limit, and the page granularity used by
/// [`DbTable::scan`].
#[derive(Debug, Clone, PartialEq)]
pub struct TableQuery {
    /// Row selector (`T('a,:,b,', :)`).
    pub rows: KeySel,
    /// Column selector (`T(:, 'c,')`).
    pub cols: KeySel,
    /// Keep at most this many entries (row-major key order).
    pub limit: Option<usize>,
    /// Rows per page for [`DbTable::scan`].
    pub page_rows: usize,
}

impl Default for TableQuery {
    fn default() -> Self {
        TableQuery { rows: KeySel::All, cols: KeySel::All, limit: None, page_rows: 1024 }
    }
}

impl TableQuery {
    /// `T(:, :)`.
    pub fn all() -> Self {
        TableQuery::default()
    }

    pub fn rows(mut self, sel: KeySel) -> Self {
        self.rows = sel;
        self
    }

    pub fn cols(mut self, sel: KeySel) -> Self {
        self.cols = sel;
        self
    }

    pub fn limit(mut self, n: usize) -> Self {
        self.limit = Some(n);
        self
    }

    pub fn page_rows(mut self, n: usize) -> Self {
        self.page_rows = n.max(1);
        self
    }
}

/// The engine-side server binding (`DBserver`): table namespace ops plus
/// `bind`, which produces the table surface as a trait object.
pub trait DbServer: Send + Sync {
    /// Which engine this server speaks.
    fn kind(&self) -> DbKind;

    /// List the tables/arrays the engine currently stores (sorted).
    fn ls(&self) -> Vec<String>;

    /// Does a table of this name exist?
    fn exists(&self, name: &str) -> bool {
        self.ls().iter().any(|t| t == name)
    }

    /// Drop a table (and any engine-side companion tables it maintains).
    fn delete_table(&self, name: &str) -> Result<()>;

    /// Bind a logical table (the `T = DB('table')` call). Engines that
    /// materialise storage lazily (SciDB, SQL) create it at first
    /// `put_assoc`; key-value engines create the schema tables eagerly.
    fn bind(&self, name: &str, opts: &BindOpts) -> Result<Box<dyn DbTable>>;
}

/// A bound table (`DBtable`): every engine speaks [`Assoc`] in both
/// directions, which is what makes cross-engine CAST a pair of trait
/// calls.
pub trait DbTable: Send + Sync {
    /// The logical table name this binding points at.
    fn name(&self) -> &str;

    /// Store an associative array (string- or numeric-valued),
    /// **replacing** any previous contents — on every engine (create-once
    /// engines recreate storage; the key-value engine clears its schema
    /// tables first). Engine-native handles keep merge/append semantics
    /// for ingest.
    fn put_assoc(&self, a: &Assoc) -> Result<()>;

    /// Read the whole table back (`T(:, :)`). A bound table with no
    /// stored contents reads as the empty assoc on every engine, whether
    /// the engine materialised storage at bind time or not.
    fn get_assoc(&self) -> Result<Assoc> {
        self.query(&TableQuery::all())
    }

    /// Stored entry count (0 for a bound table with no contents).
    fn nnz(&self) -> Result<usize>;

    /// The `T(r, c)` query: selectors pushed down into the engine, result
    /// normalised so all engines agree exactly.
    fn query(&self, q: &TableQuery) -> Result<Assoc>;

    /// Paged read: pages of at most `q.page_rows` result rows, fetched
    /// engine-side page by page (the D4M.jl table-iterator pattern) so a
    /// larger-than-memory result never materialises at once.
    ///
    /// Pages carry **raw stored values** (always string-valued assocs,
    /// no numeric inference) so that nothing is rewritten mid-stream;
    /// [`AssocPages::into_assoc`] runs the schema-less string-vs-numeric
    /// inference once over the assembled set, matching what
    /// [`DbTable::query`] infers on the same final result (when no
    /// `limit` cuts the set short).
    ///
    /// Isolation against concurrent writers is engine-defined: engines
    /// whose `put_assoc` swaps storage (SciDB, SQL) pin one table
    /// generation at `scan` creation; the key-value engine scans the
    /// live table (Accumulo semantics — no snapshot isolation in the
    /// substrate), so a concurrent writer may be visible mid-scan.
    fn scan(&self, q: &TableQuery) -> Result<AssocPages>;

    /// Entry-at-a-time read: a lazily-pulled stream of the **raw stored**
    /// `(row, col, value)` triples the selectors match, in row-major
    /// (row, then column) key order, honouring `q.limit`. This is the
    /// streaming twin of [`DbTable::scan`] and the feed for the
    /// coordinator's scan cursors (`coordinator::cursor`): the triple set
    /// it yields is exactly the set [`DbTable::query`] would return for
    /// the same `q`, before the one-shot string-vs-numeric inference
    /// (`parse_triples` over the drained stream reproduces `query`
    /// bit-for-bit when the two run against the same table state).
    ///
    /// The default drains [`DbTable::scan`] pages lazily. The key-value
    /// engine overrides it with a genuine snapshot-pinned
    /// [`EntryStream`](crate::kvstore::EntryStream), so an open stream
    /// observes a point-in-time view and never blocks writers.
    fn scan_triples(&self, q: &TableQuery) -> Result<TripleStream> {
        let pages = self.scan(q)?;
        Ok(Box::new(pages.flat_map(
            |page| -> Vec<Result<(String, String, String)>> {
                match page {
                    Ok(a) => a.str_triples().into_iter().map(Ok).collect(),
                    Err(e) => vec![Err(e)],
                }
            },
        )))
    }
}

/// Lazily-pulled stream of raw stored `(row, col, value)` triples in
/// row-major key order — see [`DbTable::scan_triples`]. An `Err` item
/// poisons the stream (no items follow it).
pub type TripleStream = Box<dyn Iterator<Item = Result<(String, String, String)>> + Send>;

/// Page-at-a-time iterator over a [`DbTable::scan`] result.
///
/// The row keys matching the query are snapshotted up front (the
/// retained snapshot is one `String` per distinct row; the snapshot
/// *pass* costs whatever the engine's key enumeration costs — see each
/// engine's `scan`); cell values are then fetched lazily, one page of
/// rows per `next()`, through an engine-provided fetch closure. Pages
/// are disjoint in row keys and arrive in sorted row order.
pub struct AssocPages {
    pages: std::vec::IntoIter<Vec<String>>,
    fetch: PageFetch,
    remaining: Option<usize>,
    done: bool,
}

/// Engine-provided closure fetching the query result for one page of
/// row keys.
pub type PageFetch = Box<dyn FnMut(&[String]) -> Result<Assoc> + Send>;

impl AssocPages {
    /// Build a paged iterator over `row_keys` (deduplicated + sorted),
    /// `page_rows` rows per page, honouring an optional total entry
    /// `limit`. `fetch` returns the query result restricted to one page
    /// of row keys.
    pub fn over_rows(
        mut row_keys: Vec<String>,
        page_rows: usize,
        limit: Option<usize>,
        fetch: PageFetch,
    ) -> Self {
        row_keys.sort();
        row_keys.dedup();
        let pages: Vec<Vec<String>> =
            row_keys.chunks(page_rows.max(1)).map(|c| c.to_vec()).collect();
        AssocPages { pages: pages.into_iter(), fetch, remaining: limit, done: false }
    }

    /// Drain every page into one associative array. Pages are
    /// row-disjoint raw-value assocs, so concatenation is exact; the
    /// string-vs-numeric inference runs once here, over the assembled
    /// set (with a `limit`, over the truncated set).
    pub fn into_assoc(self) -> Result<Assoc> {
        let mut triples: Vec<(String, String, String)> = Vec::new();
        for page in self {
            triples.extend(page?.str_triples());
        }
        crate::assoc::io::parse_triples(triples)
    }
}

impl Iterator for AssocPages {
    type Item = Result<Assoc>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.done || self.remaining == Some(0) {
            self.done = true;
            return None;
        }
        loop {
            let page = self.pages.next()?;
            let a = match (self.fetch)(&page) {
                Ok(a) => a,
                Err(e) => {
                    self.done = true;
                    return Some(Err(e));
                }
            };
            let a = match self.remaining {
                Some(n) if a.nnz() >= n => {
                    self.done = true;
                    truncate_assoc(&a, n)
                }
                Some(n) => {
                    self.remaining = Some(n - a.nnz());
                    a
                }
                None => a,
            };
            if a.is_empty() {
                if self.done {
                    return None;
                }
                continue; // a page whose rows were fully filtered out
            }
            return Some(Ok(a));
        }
    }
}

/// Keep the first `n` entries in row-major key order (used for `limit`).
pub(crate) fn truncate_assoc(a: &Assoc, n: usize) -> Assoc {
    if a.nnz() <= n {
        return a.clone();
    }
    if a.is_string_valued() {
        let t = a.str_triples();
        Assoc::from_str_triples(&t[..n])
    } else {
        let t = a.triples();
        Assoc::from_triples(&t[..n])
    }
}

/// Normalise a pushdown result: engines scan a *superset* of the selected
/// keys, then this exact client-side subsref + limit — followed by value
/// re-inference on the final set — makes every engine return the
/// identical assoc.
pub(crate) fn finish(a: Assoc, q: &TableQuery) -> Assoc {
    let a = a.subsref(&q.rows, &q.cols);
    let a = match q.limit {
        Some(n) if a.nnz() > n => truncate_assoc(&a, n),
        _ => a,
    };
    normalize_valuedness(a)
}

/// Re-run the schema-less string-vs-numeric inference on the **final**
/// result set. Engines scan different supersets (a full row on Accumulo,
/// a coordinate window on SciDB, an exact predicate on SQL), so inference
/// on the scanned set would diverge — e.g. a string table whose selected
/// cells all look numeric. Re-inferring after the trim also rebuilds the
/// value dictionary from the final set, so string-valued results carry
/// identical 1-based indices everywhere.
pub(crate) fn normalize_valuedness(a: Assoc) -> Assoc {
    if !a.is_string_valued() {
        return a;
    }
    crate::assoc::io::parse_triples(a.str_triples()).unwrap_or(a)
}

/// Zero-page scan result (e.g. for a bound-but-unwritten table).
pub(crate) fn empty_pages(q: &TableQuery) -> AssocPages {
    AssocPages::over_rows(
        vec![],
        q.page_rows,
        q.limit,
        Box::new(|_: &[String]| Ok(Assoc::empty())),
    )
}

/// Build one raw scan page: keep the stored `(row, col, value)` triples
/// the selectors match, as a string-valued assoc with **no** numeric
/// inference — pages must never rewrite stored values (`"007"` stays
/// `"007"`, not `7`).
pub(crate) fn raw_page(
    triples: Vec<(String, String, String)>,
    rows: &KeySel,
    cols: &KeySel,
) -> Assoc {
    let kept: Vec<(String, String, String)> = triples
        .into_iter()
        .filter(|(r, c, _)| rows.matches(r) && cols.matches(c))
        .collect();
    Assoc::from_str_triples(&kept)
}

/// Inclusive index bounds `(lo, hi)` of the keys a selector matches in a
/// sorted key list, or `None` when nothing matches. Array engines use
/// this to turn a [`KeySel`] into a coordinate window (`subarray`).
pub(crate) fn matched_bounds(keys: &[String], sel: &KeySel) -> Option<(usize, usize)> {
    let mut lo = None;
    let mut hi = 0usize;
    for (i, k) in keys.iter().enumerate() {
        if sel.matches(k) {
            if lo.is_none() {
                lo = Some(i);
            }
            hi = i;
        }
    }
    lo.map(|l| (l, hi))
}

/// Smallest string strictly greater than every string with prefix `p`
/// (`None` = unbounded). Key-value engines use this to turn
/// [`KeySel::Prefix`] into a scan range.
pub(crate) fn prefix_upper_bound(p: &str) -> Option<String> {
    let mut chars: Vec<char> = p.chars().collect();
    while let Some(&last) = chars.last() {
        let mut next = last as u32 + 1;
        if (0xD800..=0xDFFF).contains(&next) {
            next = 0xE000; // skip the surrogate gap
        }
        match char::from_u32(next) {
            Some(c) => {
                *chars.last_mut().unwrap() = c;
                return Some(chars.into_iter().collect());
            }
            None => {
                chars.pop(); // last char was char::MAX — carry
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[cfg_attr(miri, ignore)]
    fn prefix_bound_covers_prefixed_keys() {
        let up = prefix_upper_bound("abc").unwrap();
        assert!(up.as_str() > "abc");
        assert!(up.as_str() > "abc\u{10FFFF}zzz");
        assert_eq!(up, "abd");
        assert_eq!(prefix_upper_bound(""), None);
        let carried = prefix_upper_bound(&format!("a{}", char::MAX)).unwrap();
        assert_eq!(carried, "b");
        assert_eq!(prefix_upper_bound(&char::MAX.to_string()), None);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn matched_bounds_windows() {
        let keys: Vec<String> = ["a", "b", "c", "d"].iter().map(|s| s.to_string()).collect();
        assert_eq!(matched_bounds(&keys, &KeySel::All), Some((0, 3)));
        assert_eq!(
            matched_bounds(&keys, &KeySel::Range("b".into(), "c".into())),
            Some((1, 2))
        );
        assert_eq!(matched_bounds(&keys, &KeySel::Prefix("z".into())), None);
        assert_eq!(matched_bounds(&keys, &KeySel::keys(&["d", "a"])), Some((0, 3)));
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn truncate_keeps_row_major_prefix() {
        let a = Assoc::from_triples(&[("r1", "c1", 1.0), ("r1", "c2", 2.0), ("r2", "c1", 3.0)]);
        let t = truncate_assoc(&a, 2);
        assert_eq!(t.nnz(), 2);
        assert_eq!(t.get("r1", "c2"), 2.0);
        assert_eq!(t.get("r2", "c1"), 0.0);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn query_builder_defaults() {
        let q = TableQuery::all().limit(7).page_rows(0);
        assert!(matches!(q.rows, KeySel::All));
        assert_eq!(q.limit, Some(7));
        assert_eq!(q.page_rows, 1); // clamped
    }
}
