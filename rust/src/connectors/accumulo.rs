//! Accumulo connector implementing the **D4M 2.0 schema** (Kepner et al.,
//! 2013): each logical table is stored as four physical tables —
//!
//! * `T`      (Tedge)    — row key -> col key -> value
//! * `T_T`    (TedgeT)   — the transpose, for fast column queries
//! * `T_Deg`  (TedgeDeg) — column degrees, maintained by a summing
//!                          combiner at write time
//! * `T_Txt`  (TedgeTxt) — optional raw-text side table
//!
//! This dual-table + degree design is what made the record ingest and
//! query rates of the D4M/Accumulo papers possible; the pipeline and
//! Graphulo layers build directly on it.

use std::sync::Arc;

use crate::assoc::{io::fmt_num, Assoc};
use crate::error::Result;
use crate::kvstore::{
    BatchWriter, Entry, IterConfig, Key, KvStore, RowRange, Table, WriterConfig,
};

/// Options for binding a D4M table.
#[derive(Debug, Clone)]
pub struct D4mTableConfig {
    /// Maintain the transpose table (needed for column queries).
    pub transpose: bool,
    /// Maintain the degree table.
    pub degrees: bool,
    /// Split points for the main table (row keyspace).
    pub splits: Vec<String>,
    /// Split points for the transpose + degree tables (column keyspace —
    /// usually a different alphabet than the rows, e.g. `word|...`).
    pub transpose_splits: Vec<String>,
    /// BatchWriter tuning for [`D4mTable::writer`].
    pub writer: WriterConfig,
}

impl Default for D4mTableConfig {
    fn default() -> Self {
        D4mTableConfig {
            transpose: true,
            degrees: true,
            splits: vec![],
            transpose_splits: vec![],
            writer: WriterConfig::default(),
        }
    }
}

/// The Accumulo-engine connector (owns the embedded store).
pub struct AccumuloConnector {
    store: Arc<KvStore>,
}

impl Default for AccumuloConnector {
    fn default() -> Self {
        Self::new()
    }
}

impl AccumuloConnector {
    pub fn new() -> Self {
        AccumuloConnector { store: Arc::new(KvStore::new()) }
    }

    pub fn with_store(store: Arc<KvStore>) -> Self {
        AccumuloConnector { store }
    }

    pub fn store(&self) -> Arc<KvStore> {
        self.store.clone()
    }

    /// Bind a logical D4M table, creating the physical tables if needed
    /// (the `T = DB('Tedge')` call of the MATLAB API).
    pub fn bind(&self, name: &str, cfg: &D4mTableConfig) -> Result<D4mTable> {
        let main = self.store.ensure_table(name, cfg.splits.clone());
        let transpose = if cfg.transpose {
            Some(self.store.ensure_table(&format!("{name}_T"), cfg.transpose_splits.clone()))
        } else {
            None
        };
        let degree = if cfg.degrees {
            Some(self.store.ensure_table(&format!("{name}_Deg"), cfg.transpose_splits.clone()))
        } else {
            None
        };
        Ok(D4mTable { main, transpose, degree, cfg: cfg.clone() })
    }
}

/// A bound D4M table (the `T` in `T = DB('Tedge')`).
pub struct D4mTable {
    main: Arc<Table>,
    transpose: Option<Arc<Table>>,
    degree: Option<Arc<Table>>,
    cfg: D4mTableConfig,
}

impl D4mTable {
    pub fn main(&self) -> Arc<Table> {
        self.main.clone()
    }

    pub fn transpose_table(&self) -> Option<Arc<Table>> {
        self.transpose.clone()
    }

    pub fn degree_table(&self) -> Option<Arc<Table>> {
        self.degree.clone()
    }

    /// A buffered writer that maintains all schema tables per mutation.
    pub fn writer(&self) -> D4mWriter {
        D4mWriter {
            main: BatchWriter::new(self.main.clone(), self.cfg.writer.clone()),
            transpose: self
                .transpose
                .as_ref()
                .map(|t| BatchWriter::new(t.clone(), self.cfg.writer.clone())),
            degree: self
                .degree
                .as_ref()
                .map(|t| BatchWriter::new(t.clone(), self.cfg.writer.clone())),
        }
    }

    /// Ingest an associative array (string or numeric values).
    pub fn put_assoc(&self, a: &Assoc) -> Result<()> {
        let mut w = self.writer();
        for (r, c, v) in a.str_triples() {
            w.put(&r, &c, &v);
        }
        w.flush();
        Ok(())
    }

    /// Ingest raw string triples.
    pub fn put_triples(&self, triples: &[(String, String, String)]) -> Result<()> {
        let mut w = self.writer();
        for (r, c, v) in triples {
            w.put(r, c, v);
        }
        w.flush();
        Ok(())
    }

    /// Read the whole table back as an associative array.
    pub fn get_assoc(&self) -> Result<Assoc> {
        self.get_assoc_range(&RowRange::all())
    }

    /// Read a row range as an associative array (`T('a,:,b,', :)`).
    pub fn get_assoc_range(&self, range: &RowRange) -> Result<Assoc> {
        let entries = self.main.scan(range, &IterConfig::default());
        entries_to_assoc(entries)
    }

    /// Column query via the transpose table (`T(:, 'c,')`): scans
    /// `T_T` by row = column key, then transposes back.
    pub fn get_assoc_by_col(&self, col_range: &RowRange) -> Result<Assoc> {
        match &self.transpose {
            Some(tt) => {
                let entries = tt.scan(col_range, &IterConfig::default());
                Ok(entries_to_assoc(entries)?.transpose())
            }
            None => {
                // degenerate path: full scan + client-side filter
                let a = self.get_assoc()?;
                let cols: Vec<String> = a
                    .col_keys()
                    .iter()
                    .filter(|c| col_range.contains(c))
                    .cloned()
                    .collect();
                Ok(a.select_cols(&crate::assoc::KeySel::Keys(cols)))
            }
        }
    }

    /// Degree of one column key, answered from the degree table in O(1)
    /// scans (the D4M-schema trick for avoiding full-table counts).
    pub fn degree(&self, col: &str) -> Result<f64> {
        match &self.degree {
            Some(dt) => {
                let cfg = IterConfig { summing: true, ..Default::default() };
                let entries = dt.scan_row(col, &cfg);
                Ok(entries.first().and_then(|e| e.value.parse().ok()).unwrap_or(0.0))
            }
            None => {
                let a = self.get_assoc()?;
                Ok(a.select_cols(&crate::assoc::KeySel::keys(&[col])).logical().total())
            }
        }
    }

    /// Total entries in the main table.
    pub fn count(&self) -> usize {
        self.main.scan(&RowRange::all(), &IterConfig::default()).len()
    }
}

/// Writer that fans one logical mutation out to the schema tables.
pub struct D4mWriter {
    main: BatchWriter,
    transpose: Option<BatchWriter>,
    degree: Option<BatchWriter>,
}

impl D4mWriter {
    /// One logical cell: writes Tedge, TedgeT and a degree increment.
    pub fn put(&mut self, row: &str, col: &str, value: &str) {
        self.main.put(row, col, value);
        if let Some(t) = &mut self.transpose {
            t.put(col, row, value);
        }
        if let Some(d) = &mut self.degree {
            // degree table rows are col keys; cq = "deg"; summed at scan
            d.put(col, "deg", "1");
        }
    }

    /// Numeric convenience.
    pub fn put_num(&mut self, row: &str, col: &str, value: f64) {
        self.put(row, col, &fmt_num(value));
    }

    pub fn flush(&mut self) {
        self.main.flush();
        if let Some(t) = &mut self.transpose {
            t.flush();
        }
        if let Some(d) = &mut self.degree {
            d.flush();
        }
    }

    pub fn written(&self) -> u64 {
        self.main.written()
    }
}

/// Decode a scan result into an [`Assoc`] (numeric when every value
/// parses, string-valued otherwise).
pub fn entries_to_assoc(entries: Vec<Entry>) -> Result<Assoc> {
    let triples: Vec<(String, String, String)> =
        entries.into_iter().map(|e| (e.key.row, e.key.cq, e.value)).collect();
    crate::assoc::io::parse_triples(triples)
}

/// Encode an assoc into raw entries for table `t` (used by benches that
/// bypass the writer).
pub fn assoc_to_entries(a: &Assoc, t: &Table) -> Vec<Entry> {
    a.str_triples()
        .into_iter()
        .map(|(r, c, v)| Entry::new(Key::cell(r, c, t.next_ts()), v))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn graph_table() -> (AccumuloConnector, D4mTable) {
        let acc = AccumuloConnector::new();
        let t = acc.bind("Tedge", &D4mTableConfig::default()).unwrap();
        let a = Assoc::from_triples(&[
            ("v1", "v2", 1.0),
            ("v1", "v3", 1.0),
            ("v2", "v3", 2.0),
        ]);
        t.put_assoc(&a).unwrap();
        (acc, t)
    }

    #[test]
    fn assoc_roundtrip() {
        let (_acc, t) = graph_table();
        let a = t.get_assoc().unwrap();
        assert_eq!(a.nnz(), 3);
        assert_eq!(a.get("v2", "v3"), 2.0);
    }

    #[test]
    fn physical_tables_created() {
        let (acc, _t) = graph_table();
        let names = acc.store().list_tables();
        assert_eq!(names, vec!["Tedge", "Tedge_Deg", "Tedge_T"]);
    }

    #[test]
    fn row_range_query() {
        let (_acc, t) = graph_table();
        let a = t.get_assoc_range(&RowRange::single("v1")).unwrap();
        assert_eq!(a.row_keys(), &["v1".to_string()]);
        assert_eq!(a.nnz(), 2);
    }

    #[test]
    fn col_query_uses_transpose() {
        let (_acc, t) = graph_table();
        let a = t.get_assoc_by_col(&RowRange::single("v3")).unwrap();
        assert_eq!(a.col_keys(), &["v3".to_string()]);
        assert_eq!(a.nnz(), 2);
        assert_eq!(a.get("v2", "v3"), 2.0);
    }

    #[test]
    fn col_query_without_transpose() {
        let acc = AccumuloConnector::new();
        let cfg = D4mTableConfig { transpose: false, ..Default::default() };
        let t = acc.bind("NoT", &cfg).unwrap();
        t.put_assoc(&Assoc::from_triples(&[("a", "x", 1.0), ("b", "y", 1.0)])).unwrap();
        let a = t.get_assoc_by_col(&RowRange::single("x")).unwrap();
        assert_eq!(a.nnz(), 1);
        assert_eq!(a.get("a", "x"), 1.0);
    }

    #[test]
    fn degree_table_sums() {
        let (_acc, t) = graph_table();
        assert_eq!(t.degree("v3").unwrap(), 2.0);
        assert_eq!(t.degree("v2").unwrap(), 1.0);
        assert_eq!(t.degree("nope").unwrap(), 0.0);
    }

    #[test]
    fn string_values_survive() {
        let acc = AccumuloConnector::new();
        let t = acc.bind("Txt", &D4mTableConfig::default()).unwrap();
        let a = Assoc::from_str_triples(&[("doc1", "word|cat", "3x"), ("doc2", "word|dog", "1x")]);
        t.put_assoc(&a).unwrap();
        let b = t.get_assoc().unwrap();
        assert!(b.is_string_valued());
        assert_eq!(b.get_str("doc1", "word|cat"), Some("3x"));
    }

    #[test]
    fn rebind_existing_table() {
        let (acc, t) = graph_table();
        let t2 = acc.bind("Tedge", &D4mTableConfig::default()).unwrap();
        assert_eq!(t2.count(), t.count());
    }
}
