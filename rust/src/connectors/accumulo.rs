//! Accumulo connector implementing the **D4M 2.0 schema** (Kepner et al.,
//! 2013): each logical table is stored as four physical tables —
//!
//! * `T`      (Tedge)    — row key -> col key -> value
//! * `T_T`    (TedgeT)   — the transpose, for fast column queries
//! * `T_Deg`  (TedgeDeg) — column degrees, maintained by a summing
//!                          combiner at write time
//! * `T_Txt`  (TedgeTxt) — optional raw-text side table
//!
//! This dual-table + degree design is what made the record ingest and
//! query rates of the D4M/Accumulo papers possible; the pipeline and
//! Graphulo layers build directly on it.

use std::sync::Arc;

use crate::assoc::{io::fmt_num, Assoc, KeySel};
use crate::error::{D4mError, Result};
use crate::kvstore::{
    BatchWriter, Entry, EntryStream, IterConfig, Key, KvStore, RowRange, Table, WriterConfig,
};

use super::api::{self, AssocPages, BindOpts, DbServer, DbTable, TableQuery, TripleStream};
use super::DbKind;

/// Options for binding a D4M table.
#[derive(Debug, Clone)]
pub struct D4mTableConfig {
    /// Maintain the transpose table (needed for column queries).
    pub transpose: bool,
    /// Maintain the degree table.
    pub degrees: bool,
    /// Split points for the main table (row keyspace).
    pub splits: Vec<String>,
    /// Split points for the transpose + degree tables (column keyspace —
    /// usually a different alphabet than the rows, e.g. `word|...`).
    pub transpose_splits: Vec<String>,
    /// BatchWriter tuning for [`D4mTable::writer`].
    pub writer: WriterConfig,
}

impl Default for D4mTableConfig {
    fn default() -> Self {
        D4mTableConfig {
            transpose: true,
            degrees: true,
            splits: vec![],
            transpose_splits: vec![],
            writer: WriterConfig::default(),
        }
    }
}

/// The Accumulo-engine connector (owns the embedded store). Cloning is
/// cheap and shares the store — handy for registering the same engine in
/// a [`crate::polystore::Polystore`] while keeping a native handle.
#[derive(Clone)]
pub struct AccumuloConnector {
    store: Arc<KvStore>,
}

impl Default for AccumuloConnector {
    fn default() -> Self {
        Self::new()
    }
}

impl AccumuloConnector {
    pub fn new() -> Self {
        AccumuloConnector { store: Arc::new(KvStore::new()) }
    }

    pub fn with_store(store: Arc<KvStore>) -> Self {
        AccumuloConnector { store }
    }

    pub fn store(&self) -> Arc<KvStore> {
        self.store.clone()
    }

    /// Bind a logical D4M table, creating the physical tables if needed
    /// (the `T = DB('Tedge')` call of the MATLAB API).
    ///
    /// The `_T`/`_Deg` companion namespace is reserved (in both
    /// directions — see the [`DbServer`] notes); every bind path,
    /// native or trait, enforces it here. Companions created next to a
    /// **pre-existing** main table (e.g. a Graphulo product being
    /// promoted to a full D4M table) are backfilled from its contents,
    /// so column queries and degrees stay correct.
    pub fn bind(&self, name: &str, cfg: &D4mTableConfig) -> Result<D4mTable> {
        for suffix in ["_T", "_Deg"] {
            if let Some(base) = name.strip_suffix(suffix) {
                if !base.is_empty() && self.store.table(base).is_some() {
                    return Err(D4mError::InvalidArg(format!(
                        "table name {name} collides with the {suffix} companion \
                         namespace of existing table {base}"
                    )));
                }
            }
        }
        let pre_existing = self.store.table(name).is_some();
        if !pre_existing {
            for suffix in ["_T", "_Deg"] {
                let companion = format!("{name}{suffix}");
                if self.store.table(&companion).is_some() {
                    return Err(D4mError::InvalidArg(format!(
                        "binding {name} would adopt existing table {companion} \
                         as a schema companion"
                    )));
                }
            }
        }
        let main = self.store.ensure_table(name, cfg.splits.clone())?;
        let mut fresh_transpose = false;
        let mut fresh_degree = false;
        let transpose = if cfg.transpose {
            let full = format!("{name}_T");
            fresh_transpose = self.store.table(&full).is_none();
            Some(self.store.ensure_table(&full, cfg.transpose_splits.clone())?)
        } else {
            None
        };
        let degree = if cfg.degrees {
            let full = format!("{name}_Deg");
            fresh_degree = self.store.table(&full).is_none();
            Some(self.store.ensure_table(&full, cfg.transpose_splits.clone())?)
        } else {
            None
        };
        let table = D4mTable {
            name: name.to_string(),
            store: self.store.clone(),
            main,
            transpose,
            degree,
            cfg: cfg.clone(),
        };
        // a companion created next to a pre-existing main must reflect
        // its contents, or column queries / degrees would read empty
        if pre_existing && (fresh_transpose || fresh_degree) {
            table.backfill_companions(fresh_transpose, fresh_degree)?;
        }
        Ok(table)
    }
}

/// A bound D4M table (the `T` in `T = DB('Tedge')`).
pub struct D4mTable {
    name: String,
    store: Arc<KvStore>,
    main: Arc<Table>,
    transpose: Option<Arc<Table>>,
    degree: Option<Arc<Table>>,
    cfg: D4mTableConfig,
}

impl D4mTable {
    pub fn main(&self) -> Arc<Table> {
        self.main.clone()
    }

    pub fn transpose_table(&self) -> Option<Arc<Table>> {
        self.transpose.clone()
    }

    pub fn degree_table(&self) -> Option<Arc<Table>> {
        self.degree.clone()
    }

    /// A buffered writer that maintains all schema tables per mutation.
    pub fn writer(&self) -> D4mWriter {
        D4mWriter {
            main: BatchWriter::new(self.main.clone(), self.cfg.writer.clone()),
            transpose: self
                .transpose
                .as_ref()
                .map(|t| BatchWriter::new(t.clone(), self.cfg.writer.clone())),
            degree: self
                .degree
                .as_ref()
                .map(|t| BatchWriter::new(t.clone(), self.cfg.writer.clone())),
        }
    }

    /// Ingest an associative array (string or numeric values).
    pub fn put_assoc(&self, a: &Assoc) -> Result<()> {
        let mut w = self.writer();
        for (r, c, v) in a.str_triples() {
            w.put(&r, &c, &v)?;
        }
        w.flush()
    }

    /// Ingest raw string triples.
    pub fn put_triples(&self, triples: &[(String, String, String)]) -> Result<()> {
        let mut w = self.writer();
        for (r, c, v) in triples {
            w.put(r, c, v)?;
        }
        w.flush()
    }

    /// Read the whole table back as an associative array.
    pub fn get_assoc(&self) -> Result<Assoc> {
        self.get_assoc_range(&RowRange::all())
    }

    /// Read a row range as an associative array (`T('a,:,b,', :)`).
    /// Entries stream out of a tablet snapshot straight into the assoc
    /// builder — no intermediate `Vec<Entry>`, no lock held while
    /// decoding.
    pub fn get_assoc_range(&self, range: &RowRange) -> Result<Assoc> {
        entries_to_assoc(self.main.scan_stream(range, &IterConfig::default()))
    }

    /// Column query via the transpose table (`T(:, 'c,')`): scans
    /// `T_T` by row = column key, then transposes back.
    pub fn get_assoc_by_col(&self, col_range: &RowRange) -> Result<Assoc> {
        match &self.transpose {
            Some(tt) => {
                let entries = tt.scan_stream(col_range, &IterConfig::default());
                Ok(entries_to_assoc(entries)?.transpose())
            }
            None => {
                // degenerate path: full scan + client-side filter
                let a = self.get_assoc()?;
                let cols: Vec<String> = a
                    .col_keys()
                    .iter()
                    .filter(|c| col_range.contains(c))
                    .cloned()
                    .collect();
                Ok(a.select_cols(&crate::assoc::KeySel::Keys(cols)))
            }
        }
    }

    /// Degree of one column key, answered from the degree table in O(1)
    /// scans (the D4M-schema trick for avoiding full-table counts).
    pub fn degree(&self, col: &str) -> Result<f64> {
        match &self.degree {
            Some(dt) => {
                let cfg = IterConfig { summing: true, ..Default::default() };
                let entries = dt.scan_row(col, &cfg);
                Ok(entries.first().and_then(|e| e.value.parse().ok()).unwrap_or(0.0))
            }
            None => {
                let a = self.get_assoc()?;
                Ok(a.select_cols(&crate::assoc::KeySel::keys(&[col])).logical().total())
            }
        }
    }

    /// Total entries in the main table (streamed, never materialised).
    pub fn count(&self) -> usize {
        self.main.scan_stream(&RowRange::all(), &IterConfig::default()).count()
    }

    /// Rebuild newly created companion tables from the main table's
    /// current contents (binding schema tables onto a table that already
    /// held data). Streams a main-table snapshot while writing the
    /// companions. Not synchronised with concurrent writers.
    fn backfill_companions(&self, transpose: bool, degrees: bool) -> Result<()> {
        for e in self.main.scan_stream(&RowRange::all(), &IterConfig::default()) {
            if transpose {
                if let Some(t) = &self.transpose {
                    t.put(&e.key.cq, &e.key.row, &e.value)?;
                }
            }
            if degrees {
                if let Some(d) = &self.degree {
                    d.put(&e.key.cq, "deg", "1")?;
                }
            }
        }
        Ok(())
    }

    /// Tombstone every live cell in the schema tables (the key-value
    /// equivalent of dropping and recreating the table, without
    /// invalidating held table handles). Clears the **physical**
    /// `_T`/`_Deg` companions resolved from the store — not just the
    /// ones this binding attached — so a binding created with
    /// `transpose: false` cannot leave stale companion data behind.
    pub fn clear(&self) -> Result<()> {
        let mut tables: Vec<Arc<Table>> = vec![self.main.clone()];
        for suffix in ["_T", "_Deg"] {
            if let Some(t) = self.store.table(&format!("{}{suffix}", self.name)) {
                tables.push(t);
            }
        }
        for t in &tables {
            // streaming over the snapshot while writing tombstones into
            // the same table is safe: the open stream reads frozen
            // segments the deletes cannot touch
            for e in t.scan_stream(&RowRange::all(), &IterConfig::default()) {
                t.delete(&e.key.row, &e.key.cq)?;
            }
        }
        Ok(())
    }

    /// Unified `T(r, c)` query with engine-side pushdown: row selectors
    /// become main-table range scans; a pure column query routes through
    /// the transpose table; the residual subsref normalises exactly.
    fn query_pushdown(&self, q: &TableQuery) -> Result<Assoc> {
        let cfg = IterConfig::default();
        let a = match keysel_row_ranges(&q.rows) {
            Some(ranges) => {
                // per-range streams chained lazily: each range's
                // snapshot is acquired only when the previous range is
                // exhausted
                entries_to_assoc(ranges.iter().flat_map(|r| self.main.scan_stream(r, &cfg)))?
            }
            None => match (&self.transpose, keysel_row_ranges(&q.cols)) {
                // rows unconstrained, cols constrained: scan the
                // transpose by column key, then flip back
                (Some(tt), Some(col_ranges)) => {
                    entries_to_assoc(col_ranges.iter().flat_map(|r| tt.scan_stream(r, &cfg)))?
                        .transpose()
                }
                _ => D4mTable::get_assoc(self)?,
            },
        };
        Ok(api::finish(a, q))
    }

    /// Distinct row keys currently stored under the selector, via the
    /// substrate's **key-only** scan ([`Table::scan_row_keys`]): no values
    /// are materialised and no iterator stack runs before the first page.
    /// Rows that turn out fully tombstoned yield empty pages downstream,
    /// which the pager skips — the page fetch applies versioning exactly.
    fn matching_row_keys(&self, rows: &KeySel) -> Vec<String> {
        let ranges =
            keysel_row_ranges(rows).unwrap_or_else(|| vec![RowRange::all()]);
        let mut keys: Vec<String> = Vec::new();
        for r in &ranges {
            keys.extend(self.main.scan_row_keys(r));
        }
        keys
    }
}

impl DbTable for D4mTable {
    fn name(&self) -> &str {
        &self.name
    }

    fn put_assoc(&self, a: &Assoc) -> Result<()> {
        // unified-API semantics: put replaces previous contents on every
        // engine (the native D4mTable::put_assoc keeps merge semantics
        // for the ingest pipeline). The write maintains every *physical*
        // companion, not just the ones this binding attached, so a
        // `transpose: false` binding can't desynchronise a transpose
        // another binding relies on.
        self.clear()?;
        let transpose = self.store.table(&format!("{}_T", self.name));
        let degree = self.store.table(&format!("{}_Deg", self.name));
        let mut w = D4mWriter {
            main: BatchWriter::new(self.main.clone(), self.cfg.writer.clone()),
            transpose: transpose.map(|t| BatchWriter::new(t, self.cfg.writer.clone())),
            degree: degree.map(|d| BatchWriter::new(d, self.cfg.writer.clone())),
        };
        for (r, c, v) in a.str_triples() {
            w.put(&r, &c, &v)?;
        }
        w.flush()
    }

    fn get_assoc(&self) -> Result<Assoc> {
        D4mTable::get_assoc(self)
    }

    fn nnz(&self) -> Result<usize> {
        Ok(self.count())
    }

    fn query(&self, q: &TableQuery) -> Result<Assoc> {
        self.query_pushdown(q)
    }

    fn scan(&self, q: &TableQuery) -> Result<AssocPages> {
        // row snapshot: with rows unconstrained but cols constrained, the
        // transpose table names the matching rows directly (its cq is the
        // original row key) — no full main-table pass needed
        let rows = match (keysel_row_ranges(&q.rows), &self.transpose, keysel_row_ranges(&q.cols))
        {
            (None, Some(tt), Some(col_ranges)) => {
                let mut keys = Vec::new();
                for r in &col_ranges {
                    for e in tt.scan_stream(r, &IterConfig::default()) {
                        keys.push(e.key.cq);
                    }
                }
                keys
            }
            _ => self.matching_row_keys(&q.rows),
        };
        let main = self.main.clone();
        let row_sel = q.rows.clone();
        let col_sel = q.cols.clone();
        let fetch = Box::new(move |page: &[String]| {
            // one streaming range scan spanning the page (keys are
            // sorted), with an exact membership filter for rows stored
            // between page keys — only the page's own triples ever
            // materialise
            let mut triples: Vec<(String, String, String)> = Vec::new();
            if let (Some(first), Some(last)) = (page.first(), page.last()) {
                let span = RowRange::inclusive(first.clone(), last.clone());
                let keys: std::collections::HashSet<&str> =
                    page.iter().map(String::as_str).collect();
                for e in main.scan_stream(&span, &IterConfig::default()) {
                    if keys.contains(e.key.row.as_str()) {
                        triples.push((e.key.row, e.key.cq, e.value));
                    }
                }
            }
            Ok(api::raw_page(triples, &row_sel, &col_sel))
        });
        Ok(AssocPages::over_rows(rows, q.page_rows, q.limit, fetch))
    }

    fn scan_triples(&self, q: &TableQuery) -> Result<TripleStream> {
        // One point-in-time snapshot covering the whole selector span,
        // pinned for the stream's entire life: a cursor holding this
        // stream observes no concurrent writes, and the frozen segments
        // are released the moment the stream (cursor) is dropped. The
        // ranges come out of `keysel_row_ranges` sorted, so chaining
        // their per-range streams keeps global row-major order.
        let cfg = IterConfig::default();
        let ranges = keysel_row_ranges(&q.rows).unwrap_or_else(|| vec![RowRange::all()]);
        let span = RowRange {
            start: ranges.first().and_then(|r| r.start.clone()),
            end: ranges.last().and_then(|r| r.end.clone()),
        };
        let snap = self.main.snapshot_range(&span);
        let streams: Vec<EntryStream> = ranges.iter().map(|r| snap.stream(r, &cfg)).collect();
        let rows = q.rows.clone();
        let cols = q.cols.clone();
        let it = streams
            .into_iter()
            .flatten()
            .filter(move |e| rows.matches(&e.key.row) && cols.matches(&e.key.cq))
            .map(|e| Ok((e.key.row, e.key.cq, e.value)));
        Ok(match q.limit {
            Some(n) => Box::new(it.take(n)),
            None => Box::new(it),
        })
    }
}

/// The D4M 2.0 physical schema reserves the `{name}_T` / `{name}_Deg`
/// namespace for a logical table's companions (exactly as on a real
/// Accumulo cluster, where all four tables share one namespace): `ls` /
/// `exists` hide companions of listed tables, and `delete_table` drops
/// them with the main table. Don't name an unrelated logical table with
/// a `_T`/`_Deg` suffix of an existing one.
impl DbServer for AccumuloConnector {
    fn kind(&self) -> DbKind {
        DbKind::Accumulo
    }

    fn ls(&self) -> Vec<String> {
        // hide the _T/_Deg companions of listed tables: engine-generic
        // callers enumerate *logical* tables, matching the other engines
        let all = self.store.list_tables();
        all.iter()
            .filter(|n| {
                let is_companion = |suffix: &str| {
                    n.strip_suffix(suffix)
                        .map(|base| all.iter().any(|t| t == base))
                        .unwrap_or(false)
                };
                !is_companion("_T") && !is_companion("_Deg")
            })
            .cloned()
            .collect()
    }

    fn delete_table(&self, name: &str) -> Result<()> {
        self.store.drop_table(name)?;
        // companion schema tables go with the main table
        let _ = self.store.drop_table(&format!("{name}_T"));
        let _ = self.store.drop_table(&format!("{name}_Deg"));
        Ok(())
    }

    fn bind(&self, name: &str, opts: &BindOpts) -> Result<Box<dyn DbTable>> {
        // the namespace reservation is enforced in the inherent bind, so
        // every path (native, trait, coordinator) is covered
        let cfg = D4mTableConfig {
            transpose: opts.transpose,
            degrees: opts.degrees,
            splits: opts.splits.clone(),
            transpose_splits: opts.transpose_splits.clone(),
            writer: WriterConfig::default(),
        };
        Ok(Box::new(AccumuloConnector::bind(self, name, &cfg)?))
    }
}

/// Lower a [`KeySel`] to key-value scan ranges (`None` = full scan). The
/// ranges cover a superset of the matching keys; [`api::finish`] trims.
fn keysel_row_ranges(sel: &KeySel) -> Option<Vec<RowRange>> {
    match sel {
        KeySel::All => None,
        KeySel::Keys(ks) => {
            let mut ks = ks.clone();
            ks.sort();
            ks.dedup();
            Some(ks.iter().map(|k| RowRange::single(k)).collect())
        }
        KeySel::Range(lo, hi) => Some(vec![RowRange::inclusive(lo.clone(), hi.clone())]),
        KeySel::Prefix(p) => {
            Some(vec![RowRange { start: Some(p.clone()), end: api::prefix_upper_bound(p) }])
        }
    }
}

/// Writer that fans one logical mutation out to the schema tables.
pub struct D4mWriter {
    main: BatchWriter,
    transpose: Option<BatchWriter>,
    degree: Option<BatchWriter>,
}

impl D4mWriter {
    /// One logical cell: writes Tedge, TedgeT and a degree increment.
    pub fn put(&mut self, row: &str, col: &str, value: &str) -> Result<()> {
        self.main.put(row, col, value)?;
        if let Some(t) = &mut self.transpose {
            t.put(col, row, value)?;
        }
        if let Some(d) = &mut self.degree {
            // degree table rows are col keys; cq = "deg"; summed at scan
            d.put(col, "deg", "1")?;
        }
        Ok(())
    }

    /// Numeric convenience.
    pub fn put_num(&mut self, row: &str, col: &str, value: f64) -> Result<()> {
        self.put(row, col, &fmt_num(value))
    }

    pub fn flush(&mut self) -> Result<()> {
        self.main.flush()?;
        if let Some(t) = &mut self.transpose {
            t.flush()?;
        }
        if let Some(d) = &mut self.degree {
            d.flush()?;
        }
        Ok(())
    }

    pub fn written(&self) -> u64 {
        self.main.written()
    }
}

/// Decode a scan result into an [`Assoc`] (numeric when every value
/// parses, string-valued otherwise). Accepts anything yielding entries —
/// a materialised `Vec<Entry>` or a streaming scan cursor — so callers
/// can pipe `scan_stream` output straight in.
pub fn entries_to_assoc(entries: impl IntoIterator<Item = Entry>) -> Result<Assoc> {
    let triples: Vec<(String, String, String)> =
        entries.into_iter().map(|e| (e.key.row, e.key.cq, e.value)).collect();
    crate::assoc::io::parse_triples(triples)
}

/// Encode an assoc into raw entries for table `t` (used by benches that
/// bypass the writer).
pub fn assoc_to_entries(a: &Assoc, t: &Table) -> Vec<Entry> {
    a.str_triples()
        .into_iter()
        .map(|(r, c, v)| Entry::new(Key::cell(r, c, t.next_ts()), v))
        .collect()
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests panic by design
mod tests {
    use super::*;

    fn graph_table() -> (AccumuloConnector, D4mTable) {
        let acc = AccumuloConnector::new();
        let t = acc.bind("Tedge", &D4mTableConfig::default()).unwrap();
        let a = Assoc::from_triples(&[
            ("v1", "v2", 1.0),
            ("v1", "v3", 1.0),
            ("v2", "v3", 2.0),
        ]);
        t.put_assoc(&a).unwrap();
        (acc, t)
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn assoc_roundtrip() {
        let (_acc, t) = graph_table();
        let a = t.get_assoc().unwrap();
        assert_eq!(a.nnz(), 3);
        assert_eq!(a.get("v2", "v3"), 2.0);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn physical_tables_created() {
        let (acc, _t) = graph_table();
        let names = acc.store().list_tables();
        assert_eq!(names, vec!["Tedge", "Tedge_Deg", "Tedge_T"]);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn row_range_query() {
        let (_acc, t) = graph_table();
        let a = t.get_assoc_range(&RowRange::single("v1")).unwrap();
        assert_eq!(a.row_keys(), &["v1".to_string()]);
        assert_eq!(a.nnz(), 2);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn col_query_uses_transpose() {
        let (_acc, t) = graph_table();
        let a = t.get_assoc_by_col(&RowRange::single("v3")).unwrap();
        assert_eq!(a.col_keys(), &["v3".to_string()]);
        assert_eq!(a.nnz(), 2);
        assert_eq!(a.get("v2", "v3"), 2.0);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn col_query_without_transpose() {
        let acc = AccumuloConnector::new();
        let cfg = D4mTableConfig { transpose: false, ..Default::default() };
        let t = acc.bind("NoT", &cfg).unwrap();
        t.put_assoc(&Assoc::from_triples(&[("a", "x", 1.0), ("b", "y", 1.0)])).unwrap();
        let a = t.get_assoc_by_col(&RowRange::single("x")).unwrap();
        assert_eq!(a.nnz(), 1);
        assert_eq!(a.get("a", "x"), 1.0);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn degree_table_sums() {
        let (_acc, t) = graph_table();
        assert_eq!(t.degree("v3").unwrap(), 2.0);
        assert_eq!(t.degree("v2").unwrap(), 1.0);
        assert_eq!(t.degree("nope").unwrap(), 0.0);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn string_values_survive() {
        let acc = AccumuloConnector::new();
        let t = acc.bind("Txt", &D4mTableConfig::default()).unwrap();
        let a = Assoc::from_str_triples(&[("doc1", "word|cat", "3x"), ("doc2", "word|dog", "1x")]);
        t.put_assoc(&a).unwrap();
        let b = t.get_assoc().unwrap();
        assert!(b.is_string_valued());
        assert_eq!(b.get_str("doc1", "word|cat"), Some("3x"));
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn rebind_existing_table() {
        let (acc, t) = graph_table();
        let t2 = acc.bind("Tedge", &D4mTableConfig::default()).unwrap();
        assert_eq!(t2.count(), t.count());
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn bind_backfills_companions_for_out_of_band_table() {
        let acc = AccumuloConnector::new();
        // a main-only table populated directly in the store (the shape of
        // a Graphulo product being promoted to a full D4M table)
        let raw = acc.store().ensure_table("C", vec![]).unwrap();
        raw.put("r1", "c1", "2").unwrap();
        raw.put("r2", "c1", "3").unwrap();
        let t = acc.bind("C", &D4mTableConfig::default()).unwrap();
        // the freshly created transpose answers column queries correctly
        let col = t.get_assoc_by_col(&RowRange::single("c1")).unwrap();
        assert_eq!(col.nnz(), 2);
        assert_eq!(col.get("r2", "c1"), 3.0);
        // and the degree table reflects the pre-existing cells
        assert_eq!(t.degree("c1").unwrap(), 2.0);
        // rebinding must not double the backfill
        let t2 = acc.bind("C", &D4mTableConfig::default()).unwrap();
        assert_eq!(t2.degree("c1").unwrap(), 2.0);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn bind_rejects_namespace_collisions_on_native_path() {
        let acc = AccumuloConnector::new();
        acc.bind("foo", &D4mTableConfig::default()).unwrap();
        // the inherent bind (the coordinator's path) is guarded too
        assert!(acc.bind("foo_T", &D4mTableConfig::default()).is_err());
        let acc2 = AccumuloConnector::new();
        acc2.bind("bar_T", &D4mTableConfig::default()).unwrap();
        assert!(acc2.bind("bar", &D4mTableConfig::default()).is_err());
    }
}
