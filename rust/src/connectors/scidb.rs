//! SciDB connector: "for the purpose of D4M, SciDB arrays are nothing but
//! associative arrays" (the paper). The connector maps string keys to
//! dense integer coordinates through per-array dimension dictionaries and
//! pushes ops (spgemm, filter, subarray) into the store.
//!
//! Implements the unified [`DbServer`]/[`DbTable`] binding surface:
//! [`TableQuery`] selectors are lowered to `subarray` coordinate windows
//! through the dictionaries, so range/prefix queries only touch the
//! chunks overlapping the window.

// unwrap/expect are disallowed repo-wide (clippy.toml); this module's
// call sites predate the policy and are tracked for burn-down in
// EXPERIMENTS.md — never-panic modules carry no such allow.
#![allow(clippy::disallowed_methods)]
use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use crate::arraystore::{ArraySchema, ArrayStore, StoredArray};
use crate::assoc::{Assoc, KeySel};
use crate::error::{D4mError, Result};

use super::api::{self, AssocPages, BindOpts, DbServer, DbTable, TableQuery};
use super::DbKind;

/// Per-array key dictionaries: sorted string keys <-> dense coordinates.
/// `val_keys` carries the value dictionary of string-valued assocs (cells
/// then store 1-based indices into it), so non-numeric arrays round-trip.
#[derive(Debug, Clone, Default)]
pub struct DimDict {
    pub row_keys: Vec<String>,
    pub col_keys: Vec<String>,
    pub val_keys: Option<Vec<String>>,
}

struct SciDbInner {
    store: ArrayStore,
    dicts: RwLock<HashMap<String, DimDict>>,
}

/// The SciDB-engine connector (owns the embedded store + dictionaries).
/// Cloning is cheap and shares the store.
#[derive(Clone)]
pub struct SciDbConnector {
    inner: Arc<SciDbInner>,
}

impl Default for SciDbConnector {
    fn default() -> Self {
        Self::new()
    }
}

impl SciDbConnector {
    pub fn new() -> Self {
        SciDbConnector {
            inner: Arc::new(SciDbInner {
                store: ArrayStore::new(),
                dicts: RwLock::new(HashMap::new()),
            }),
        }
    }

    pub fn store(&self) -> &ArrayStore {
        &self.inner.store
    }

    /// Ingest an assoc as a new array with the given chunk size. The
    /// array's dimensions are the assoc's key spaces; values come from
    /// attribute `"val"` (string-valued assocs store value-dictionary
    /// indices, with the dictionary kept in the [`DimDict`]).
    pub fn put_assoc(&self, name: &str, a: &Assoc, chunk: u64) -> Result<Arc<StoredArray>> {
        let mut dicts = self.inner.dicts.write().unwrap();
        self.put_assoc_locked(&mut dicts, name, a, chunk)
    }

    /// Create + fill the array while the caller holds the dictionary
    /// write lock, so readers never pair an array with the wrong
    /// dictionary generation.
    fn put_assoc_locked(
        &self,
        dicts: &mut HashMap<String, DimDict>,
        name: &str,
        a: &Assoc,
        chunk: u64,
    ) -> Result<Arc<StoredArray>> {
        let dict = DimDict {
            row_keys: a.row_keys().to_vec(),
            col_keys: a.col_keys().to_vec(),
            val_keys: a.val_keys().map(|v| v.to_vec()),
        };
        let shape = (dict.row_keys.len().max(1) as u64, dict.col_keys.len().max(1) as u64);
        let arr = self.inner.store.create(ArraySchema::new(name, shape, chunk, &["val"]))?;
        let cells: Vec<(u64, u64, Vec<f64>)> = a
            .matrix()
            .to_triples()
            .into_iter()
            .map(|(r, c, v)| (r as u64, c as u64, vec![v]))
            .collect();
        arr.put_batch(cells)?;
        dicts.insert(name.to_string(), dict);
        Ok(arr)
    }

    /// Read an array back as an assoc through its dictionaries.
    pub fn get_assoc(&self, name: &str) -> Result<Assoc> {
        let (arr, dict) = {
            // resolve (array, dict) under one read lock — a concurrent
            // replace swaps both under the write lock, so the pair is
            // always from one generation
            let dicts = self.inner.dicts.read().unwrap();
            let arr = self.inner.store.array_or_err(name)?;
            let dict = dicts
                .get(name)
                .cloned()
                .ok_or_else(|| D4mError::NotFound(format!("dimension dictionary for {name}")))?;
            (arr, dict)
        };
        let cells = arr.scan_attr("val")?;
        decode_cells(&dict, &cells)
    }

    /// Register a dictionary for an array produced in-store (e.g. by
    /// spgemm) so it can be read back as an assoc.
    pub fn set_dict(&self, name: &str, dict: DimDict) {
        self.inner.dicts.write().unwrap().insert(name.to_string(), dict);
    }

    pub fn dict(&self, name: &str) -> Option<DimDict> {
        self.inner.dicts.read().unwrap().get(name).cloned()
    }

    /// In-database matrix multiply of two ingested assocs: runs
    /// [`ArrayStore::spgemm`] in the store, wires up the result
    /// dictionary, and returns the product as an assoc.
    ///
    /// Requires `a`'s column keys to equal `b`'s row keys (the connector
    /// aligns them before ingest when called through
    /// [`SciDbConnector::matmul_assocs`]).
    pub fn spgemm(&self, a: &str, b: &str, out: &str) -> Result<Assoc> {
        let da = self.dict(a).ok_or_else(|| D4mError::NotFound(format!("dict {a}")))?;
        let db = self.dict(b).ok_or_else(|| D4mError::NotFound(format!("dict {b}")))?;
        if da.col_keys != db.row_keys {
            return Err(D4mError::Shape(
                "spgemm inner dictionaries differ; ingest aligned arrays first".into(),
            ));
        }
        self.inner.store.spgemm(a, b, out)?;
        self.set_dict(
            out,
            DimDict { row_keys: da.row_keys, col_keys: db.col_keys, val_keys: None },
        );
        self.get_assoc(out)
    }

    /// Convenience: ingest two assocs aligned on their shared inner keys,
    /// multiply in-store, return the result (the "in-database linear
    /// algebra without export" demo).
    pub fn matmul_assocs(&self, a: &Assoc, b: &Assoc, prefix: &str, chunk: u64) -> Result<Assoc> {
        // align: restrict A's cols and B's rows to the shared key set
        let (inner, _, _) =
            crate::util::intersect_sorted_keys(a.col_keys(), b.row_keys());
        let a_aligned = a.select_cols(&KeySel::Keys(inner.clone()));
        let b_aligned = b.select_rows(&KeySel::Keys(inner));
        // re-intersect after compaction (some keys may have emptied)
        let (inner2, _, _) =
            crate::util::intersect_sorted_keys(a_aligned.col_keys(), b_aligned.row_keys());
        let a_aligned = a_aligned.select_cols(&KeySel::Keys(inner2.clone()));
        let b_aligned = b_aligned.select_rows(&KeySel::Keys(inner2));
        if a_aligned.col_keys() != b_aligned.row_keys() {
            return Err(D4mError::Shape("alignment failed".into()));
        }
        self.put_assoc(&format!("{prefix}_a"), &a_aligned, chunk)?;
        self.put_assoc(&format!("{prefix}_b"), &b_aligned, chunk)?;
        self.spgemm(&format!("{prefix}_a"), &format!("{prefix}_b"), &format!("{prefix}_c"))
    }
}

/// Decode `(i, j, cell)` coordinates into raw `(row, col, value)` string
/// triples through a dictionary (string-valued arrays resolve their
/// value dictionary; numeric arrays render the number).
fn decode_cells_raw(
    dict: &DimDict,
    cells: &[(u64, u64, f64)],
) -> Result<Vec<(String, String, String)>> {
    let key = |ks: &[String], i: u64| -> Result<String> {
        ks.get(i as usize)
            .cloned()
            .ok_or_else(|| D4mError::Parse(format!("coordinate {i} outside dictionary")))
    };
    let mut t: Vec<(String, String, String)> = Vec::with_capacity(cells.len());
    for &(i, j, v) in cells {
        let s = match &dict.val_keys {
            Some(vals) => (v as usize)
                .checked_sub(1)
                .and_then(|k| vals.get(k))
                .cloned()
                .ok_or_else(|| {
                    D4mError::Parse(format!("value index {v} outside value dictionary"))
                })?,
            None => crate::assoc::io::fmt_num(v),
        };
        t.push((key(&dict.row_keys, i)?, key(&dict.col_keys, j)?, s));
    }
    Ok(t)
}

/// Decode into an assoc, with the same string/numeric inference as the
/// other engines (unified-API conformance).
fn decode_cells(dict: &DimDict, cells: &[(u64, u64, f64)]) -> Result<Assoc> {
    crate::assoc::io::parse_triples(decode_cells_raw(dict, cells)?)
}

/// `T(r, c)` query against one pinned array generation (handle +
/// dictionary resolved together by the caller), so reads never mix table
/// states when a concurrent `put_assoc` swaps the array.
fn scidb_query_pinned(arr: &StoredArray, dict: &DimDict, q: &TableQuery) -> Result<Assoc> {
    let rb = api::matched_bounds(&dict.row_keys, &q.rows);
    let cb = api::matched_bounds(&dict.col_keys, &q.cols);
    let ((r0, r1), (c0, c1)) = match (rb, cb) {
        (Some(r), Some(c)) => (r, c),
        _ => return Ok(Assoc::empty()),
    };
    let window = arr.subarray((r0 as u64, c0 as u64), (r1 as u64, c1 as u64))?;
    let cells: Vec<(u64, u64, f64)> =
        window.into_iter().map(|(i, j, cell)| (i, j, cell[0])).collect();
    let a = decode_cells(dict, &cells)?;
    Ok(api::finish(a, q))
}

/// A bound SciDB array (created lazily at first `put_assoc`, since the
/// array schema depends on the assoc's key spaces).
pub struct SciDbTable {
    name: String,
    chunk: u64,
    conn: SciDbConnector,
}

impl SciDbTable {
    /// Atomically resolve one `(array, dictionary)` generation under the
    /// dictionary read lock (replaces hold the write lock across their
    /// whole swap). `Ok(None)` = bound but never written.
    fn pin(&self) -> Result<Option<(Arc<StoredArray>, DimDict)>> {
        let dicts = self.conn.inner.dicts.read().unwrap();
        let arr = match self.conn.inner.store.array(&self.name) {
            Some(a) => a,
            None => return Ok(None),
        };
        let dict = dicts.get(&self.name).cloned().ok_or_else(|| {
            D4mError::NotFound(format!("dimension dictionary for {}", self.name))
        })?;
        Ok(Some((arr, dict)))
    }
}

impl DbTable for SciDbTable {
    fn name(&self) -> &str {
        &self.name
    }

    fn put_assoc(&self, a: &Assoc) -> Result<()> {
        // create-once storage: replace previous contents. The whole
        // remove/drop/create/fill swap happens under the dictionary
        // write lock, so readers (which resolve under the read lock)
        // always see one consistent generation.
        let mut dicts = self.conn.inner.dicts.write().unwrap();
        dicts.remove(&self.name);
        let _ = self.conn.inner.store.drop_array(&self.name);
        self.conn.put_assoc_locked(&mut dicts, &self.name, a, self.chunk).map(|_| ())
    }

    fn get_assoc(&self) -> Result<Assoc> {
        match self.pin()? {
            Some((arr, dict)) => {
                let cells = arr.scan_attr("val")?;
                decode_cells(&dict, &cells)
            }
            None => Ok(Assoc::empty()), // bound but never written
        }
    }

    fn nnz(&self) -> Result<usize> {
        // consistent with the read path: an array whose dictionary is
        // missing is unreadable, so nnz errors the same way get_assoc does
        match self.pin()? {
            Some((arr, _)) => Ok(arr.count()),
            None => Ok(0),
        }
    }

    fn query(&self, q: &TableQuery) -> Result<Assoc> {
        match self.pin()? {
            Some((arr, dict)) => scidb_query_pinned(&arr, &dict, q),
            None => Ok(Assoc::empty()),
        }
    }

    fn scan(&self, q: &TableQuery) -> Result<AssocPages> {
        // pin one table generation (array handle + dictionary): a
        // concurrent put_assoc swaps the array, and re-resolving per
        // page would silently mix the two states
        let (arr, dict) = match self.pin()? {
            Some(p) => p,
            None => return Ok(api::empty_pages(q)), // bound but never written
        };
        let rows: Vec<String> =
            dict.row_keys.iter().filter(|k| q.rows.matches(k)).cloned().collect();
        let col_sel = q.cols.clone();
        // the column window never changes across pages — compute it once
        let cb = api::matched_bounds(&dict.col_keys, &q.cols);
        let fetch = Box::new(move |page: &[String]| {
            // raw page: window the store to the page rows (binary search —
            // page keys come from this pinned dict, sorted), decode
            // without numeric inference, filter rows by O(1) membership
            let (c0, c1) = match cb {
                Some(c) => c,
                None => return Ok(Assoc::empty()),
            };
            let (r0, r1) = match (
                dict.row_keys.binary_search(&page[0]),
                dict.row_keys.binary_search(&page[page.len() - 1]),
            ) {
                (Ok(a), Ok(b)) => (a, b),
                _ => return Ok(Assoc::empty()),
            };
            let window = arr.subarray((r0 as u64, c0 as u64), (r1 as u64, c1 as u64))?;
            let cells: Vec<(u64, u64, f64)> =
                window.into_iter().map(|(i, j, cell)| (i, j, cell[0])).collect();
            let raw = decode_cells_raw(&dict, &cells)?;
            let keys: std::collections::HashSet<&str> =
                page.iter().map(String::as_str).collect();
            let kept: Vec<(String, String, String)> = raw
                .into_iter()
                .filter(|(r, c, _)| keys.contains(r.as_str()) && col_sel.matches(c))
                .collect();
            Ok(Assoc::from_str_triples(&kept))
        });
        Ok(AssocPages::over_rows(rows, q.page_rows, q.limit, fetch))
    }
}

impl DbServer for SciDbConnector {
    fn kind(&self) -> DbKind {
        DbKind::SciDb
    }

    fn ls(&self) -> Vec<String> {
        self.inner.store.list()
    }

    fn delete_table(&self, name: &str) -> Result<()> {
        self.inner.dicts.write().unwrap().remove(name);
        self.inner.store.drop_array(name)
    }

    fn bind(&self, name: &str, opts: &BindOpts) -> Result<Box<dyn DbTable>> {
        Ok(Box::new(SciDbTable {
            name: name.to_string(),
            chunk: opts.chunk.max(1),
            conn: self.clone(),
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[cfg_attr(miri, ignore)]
    fn assoc_array_roundtrip() {
        let c = SciDbConnector::new();
        let a = Assoc::from_triples(&[("r1", "c1", 1.5), ("r2", "c2", 2.5)]);
        c.put_assoc("arr", &a, 16).unwrap();
        let b = c.get_assoc("arr").unwrap();
        assert_eq!(a, b);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn string_values_roundtrip_via_value_dictionary() {
        let c = SciDbConnector::new();
        let a = Assoc::from_str_triples(&[("r1", "c1", "red"), ("r2", "c2", "blue")]);
        c.put_assoc("strs", &a, 8).unwrap();
        let b = c.get_assoc("strs").unwrap();
        assert!(b.is_string_valued());
        assert_eq!(b.get_str("r1", "c1"), Some("red"));
        assert_eq!(b.get_str("r2", "c2"), Some("blue"));
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn in_store_spgemm_matches_client_matmul() {
        let c = SciDbConnector::new();
        let a = Assoc::from_triples(&[
            ("r1", "k1", 2.0),
            ("r1", "k2", 1.0),
            ("r2", "k2", 3.0),
        ]);
        let b = Assoc::from_triples(&[("k1", "c1", 1.0), ("k2", "c1", 4.0), ("k2", "c2", 5.0)]);
        let want = a.matmul(&b);
        let got = c.matmul_assocs(&a, &b, "mm", 8).unwrap();
        assert_eq!(want.triples(), got.triples());
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn spgemm_partial_key_overlap() {
        let c = SciDbConnector::new();
        // A has a col key B lacks, and vice versa — alignment must drop both
        let a = Assoc::from_triples(&[("r", "shared", 2.0), ("r", "only_a", 7.0)]);
        let b = Assoc::from_triples(&[("shared", "c", 3.0), ("only_b", "c", 11.0)]);
        let got = c.matmul_assocs(&a, &b, "po", 4).unwrap();
        assert_eq!(got.triples(), a.matmul(&b).triples());
        assert_eq!(got.get("r", "c"), 6.0);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn misaligned_spgemm_rejected() {
        let c = SciDbConnector::new();
        let a = Assoc::from_triples(&[("r", "x", 1.0)]);
        let b = Assoc::from_triples(&[("y", "c", 1.0)]);
        c.put_assoc("a", &a, 4).unwrap();
        c.put_assoc("b", &b, 4).unwrap();
        assert!(c.spgemm("a", "b", "c").is_err());
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn missing_dict_errors() {
        let c = SciDbConnector::new();
        // array created directly in the store, no dictionary registered
        c.store()
            .create(crate::arraystore::ArraySchema::new("raw", (4, 4), 2, &["val"]))
            .unwrap();
        assert!(c.get_assoc("raw").is_err());
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn rebind_put_replaces_contents() {
        let c = SciDbConnector::new();
        let t = DbServer::bind(&c, "arr", &BindOpts::default()).unwrap();
        t.put_assoc(&Assoc::from_triples(&[("a", "b", 1.0)])).unwrap();
        t.put_assoc(&Assoc::from_triples(&[("x", "y", 9.0)])).unwrap();
        let back = t.get_assoc().unwrap();
        assert_eq!(back.nnz(), 1);
        assert_eq!(back.get("x", "y"), 9.0);
    }
}
