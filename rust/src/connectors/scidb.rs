//! SciDB connector: "for the purpose of D4M, SciDB arrays are nothing but
//! associative arrays" (the paper). The connector maps string keys to
//! dense integer coordinates through per-array dimension dictionaries and
//! pushes ops (spgemm, filter, subarray) into the store.

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use crate::arraystore::{ArraySchema, ArrayStore, StoredArray};
use crate::assoc::Assoc;
use crate::error::{D4mError, Result};

/// Per-array key dictionaries: sorted string keys <-> dense coordinates.
#[derive(Debug, Clone, Default)]
pub struct DimDict {
    pub row_keys: Vec<String>,
    pub col_keys: Vec<String>,
}

/// The SciDB-engine connector (owns the embedded store + dictionaries).
pub struct SciDbConnector {
    store: ArrayStore,
    dicts: RwLock<HashMap<String, DimDict>>,
}

impl Default for SciDbConnector {
    fn default() -> Self {
        Self::new()
    }
}

impl SciDbConnector {
    pub fn new() -> Self {
        SciDbConnector { store: ArrayStore::new(), dicts: RwLock::new(HashMap::new()) }
    }

    pub fn store(&self) -> &ArrayStore {
        &self.store
    }

    /// Ingest an assoc as a new array with the given chunk size. The
    /// array's dimensions are the assoc's key spaces; values come from
    /// attribute `"val"`.
    pub fn put_assoc(&self, name: &str, a: &Assoc, chunk: u64) -> Result<Arc<StoredArray>> {
        let dict = DimDict { row_keys: a.row_keys().to_vec(), col_keys: a.col_keys().to_vec() };
        let shape = (dict.row_keys.len().max(1) as u64, dict.col_keys.len().max(1) as u64);
        let arr = self.store.create(ArraySchema::new(name, shape, chunk, &["val"]))?;
        let cells: Vec<(u64, u64, Vec<f64>)> = a
            .matrix()
            .to_triples()
            .into_iter()
            .map(|(r, c, v)| (r as u64, c as u64, vec![v]))
            .collect();
        arr.put_batch(cells)?;
        self.dicts.write().unwrap().insert(name.to_string(), dict);
        Ok(arr)
    }

    /// Read an array back as an assoc through its dictionaries.
    pub fn get_assoc(&self, name: &str) -> Result<Assoc> {
        let arr = self.store.array_or_err(name)?;
        let dict = self
            .dicts
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| D4mError::NotFound(format!("dimension dictionary for {name}")))?;
        let triples: Vec<(String, String, f64)> = arr
            .scan_attr("val")?
            .into_iter()
            .map(|(i, j, v)| {
                (dict.row_keys[i as usize].clone(), dict.col_keys[j as usize].clone(), v)
            })
            .collect();
        Ok(Assoc::from_triples(&triples))
    }

    /// Register a dictionary for an array produced in-store (e.g. by
    /// spgemm) so it can be read back as an assoc.
    pub fn set_dict(&self, name: &str, dict: DimDict) {
        self.dicts.write().unwrap().insert(name.to_string(), dict);
    }

    pub fn dict(&self, name: &str) -> Option<DimDict> {
        self.dicts.read().unwrap().get(name).cloned()
    }

    /// In-database matrix multiply of two ingested assocs: runs
    /// [`ArrayStore::spgemm`] in the store, wires up the result
    /// dictionary, and returns the product as an assoc.
    ///
    /// Requires `a`'s column keys to equal `b`'s row keys (the connector
    /// aligns them before ingest when called through
    /// [`SciDbConnector::matmul_assocs`]).
    pub fn spgemm(&self, a: &str, b: &str, out: &str) -> Result<Assoc> {
        let da = self.dict(a).ok_or_else(|| D4mError::NotFound(format!("dict {a}")))?;
        let db = self.dict(b).ok_or_else(|| D4mError::NotFound(format!("dict {b}")))?;
        if da.col_keys != db.row_keys {
            return Err(D4mError::Shape(
                "spgemm inner dictionaries differ; ingest aligned arrays first".into(),
            ));
        }
        self.store.spgemm(a, b, out)?;
        self.set_dict(out, DimDict { row_keys: da.row_keys, col_keys: db.col_keys });
        self.get_assoc(out)
    }

    /// Convenience: ingest two assocs aligned on their shared inner keys,
    /// multiply in-store, return the result (the "in-database linear
    /// algebra without export" demo).
    pub fn matmul_assocs(&self, a: &Assoc, b: &Assoc, prefix: &str, chunk: u64) -> Result<Assoc> {
        // align: restrict A's cols and B's rows to the shared key set
        let (inner, _, _) =
            crate::util::intersect_sorted_keys(a.col_keys(), b.row_keys());
        let a_aligned = a.select_cols(&crate::assoc::KeySel::Keys(inner.clone()));
        let b_aligned = b.select_rows(&crate::assoc::KeySel::Keys(inner));
        // re-intersect after compaction (some keys may have emptied)
        let (inner2, _, _) =
            crate::util::intersect_sorted_keys(a_aligned.col_keys(), b_aligned.row_keys());
        let a_aligned = a_aligned.select_cols(&crate::assoc::KeySel::Keys(inner2.clone()));
        let b_aligned = b_aligned.select_rows(&crate::assoc::KeySel::Keys(inner2));
        if a_aligned.col_keys() != b_aligned.row_keys() {
            return Err(D4mError::Shape("alignment failed".into()));
        }
        self.put_assoc(&format!("{prefix}_a"), &a_aligned, chunk)?;
        self.put_assoc(&format!("{prefix}_b"), &b_aligned, chunk)?;
        self.spgemm(&format!("{prefix}_a"), &format!("{prefix}_b"), &format!("{prefix}_c"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assoc_array_roundtrip() {
        let c = SciDbConnector::new();
        let a = Assoc::from_triples(&[("r1", "c1", 1.5), ("r2", "c2", 2.5)]);
        c.put_assoc("arr", &a, 16).unwrap();
        let b = c.get_assoc("arr").unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn in_store_spgemm_matches_client_matmul() {
        let c = SciDbConnector::new();
        let a = Assoc::from_triples(&[
            ("r1", "k1", 2.0),
            ("r1", "k2", 1.0),
            ("r2", "k2", 3.0),
        ]);
        let b = Assoc::from_triples(&[("k1", "c1", 1.0), ("k2", "c1", 4.0), ("k2", "c2", 5.0)]);
        let want = a.matmul(&b);
        let got = c.matmul_assocs(&a, &b, "mm", 8).unwrap();
        assert_eq!(want.triples(), got.triples());
    }

    #[test]
    fn spgemm_partial_key_overlap() {
        let c = SciDbConnector::new();
        // A has a col key B lacks, and vice versa — alignment must drop both
        let a = Assoc::from_triples(&[("r", "shared", 2.0), ("r", "only_a", 7.0)]);
        let b = Assoc::from_triples(&[("shared", "c", 3.0), ("only_b", "c", 11.0)]);
        let got = c.matmul_assocs(&a, &b, "po", 4).unwrap();
        assert_eq!(got.triples(), a.matmul(&b).triples());
        assert_eq!(got.get("r", "c"), 6.0);
    }

    #[test]
    fn misaligned_spgemm_rejected() {
        let c = SciDbConnector::new();
        let a = Assoc::from_triples(&[("r", "x", 1.0)]);
        let b = Assoc::from_triples(&[("y", "c", 1.0)]);
        c.put_assoc("a", &a, 4).unwrap();
        c.put_assoc("b", &b, 4).unwrap();
        assert!(c.spgemm("a", "b", "c").is_err());
    }

    #[test]
    fn missing_dict_errors() {
        let c = SciDbConnector::new();
        // array created directly in the store, no dictionary registered
        c.store()
            .create(crate::arraystore::ArraySchema::new("raw", (4, 4), 2, &["val"]))
            .unwrap();
        assert!(c.get_assoc("raw").is_err());
    }
}
