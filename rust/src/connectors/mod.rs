//! D4M database connectors — the `DB()` / `T = DB('table')` surface of
//! the paper (Figure 1: "D4M server bindings leverage various database
//! connectors").
//!
//! One **unified binding API** ([`api`]), three engines behind it:
//! * [`accumulo::AccumuloConnector`] — key-value tables in the D4M 2.0
//!   schema (Tedge / TedgeT / TedgeDeg / TedgeTxt).
//! * [`scidb::SciDbConnector`] — chunked arrays with in-store ops.
//! * [`sql::SqlConnector`] — relational triple tables.
//!
//! Every engine implements the object-safe [`DbServer`] / [`DbTable`]
//! traits: `bind(name, &BindOpts)` hands back a table that speaks
//! [`crate::assoc::Assoc`] in both directions, answers the paper's
//! `T(r, c)` form through [`TableQuery`] (selectors pushed down as
//! Accumulo range/transpose scans, SciDB `subarray` windows, SQL WHERE
//! predicates), and streams larger-than-memory reads through the paged
//! [`AssocPages`] iterator. Cross-engine translation (the BigDAWG
//! text-island role, [`crate::polystore`]) is a pair of trait calls, and
//! a fourth engine is one `impl` away. The conformance tests below pin
//! the contract: same data + same query = identical assoc on every
//! engine. See DESIGN.md §Connectors for the paper-to-module mapping.

pub mod accumulo;
pub mod api;
pub mod scidb;
pub mod sql;

pub use accumulo::{AccumuloConnector, D4mTable, D4mTableConfig};
pub use api::{AssocPages, BindOpts, DbServer, DbTable, TableQuery, TripleStream};
pub use scidb::{SciDbConnector, SciDbTable};
pub use sql::{SqlConnector, SqlTable};

/// Which engine a D4M binding points at (the `DBserver` type tag).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DbKind {
    Accumulo,
    SciDb,
    Sql,
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests panic by design
mod tests {
    use super::*;
    use crate::assoc::{Assoc, KeySel};

    /// One server per engine, fresh stores.
    fn engines() -> Vec<Box<dyn DbServer>> {
        vec![
            Box::new(AccumuloConnector::new()),
            Box::new(SciDbConnector::new()),
            Box::new(SqlConnector::new()),
        ]
    }

    fn sample() -> Assoc {
        Assoc::from_triples(&[
            ("apple", "x1", 1.0),
            ("apple", "y2", 2.0),
            ("banana", "x1", 3.0),
            ("berry", "y2", 4.0),
            ("cherry", "x2", 5.0),
            ("date", "y1", 6.0),
        ])
    }

    /// Run a query against every engine and demand identical results.
    fn assert_conformance(a: &Assoc, q: &TableQuery) {
        let want = {
            let full = a.subsref(&q.rows, &q.cols);
            match q.limit {
                Some(n) if full.nnz() > n => {
                    let t = full.triples();
                    Assoc::from_triples(&t[..n])
                }
                _ => full,
            }
        };
        for db in engines() {
            let t = db.bind("t", &BindOpts::default()).unwrap();
            t.put_assoc(a).unwrap();
            let got = t.query(q).unwrap();
            assert_eq!(want.triples(), got.triples(), "engine {:?}, query {q:?}", db.kind());
        }
    }

    /// Acceptance gate: a `KeySel::Range` row selector returns identical
    /// results on all three engines.
    #[test]
    #[cfg_attr(miri, ignore)]
    fn conformance_row_range() {
        assert_conformance(
            &sample(),
            &TableQuery::all().rows(KeySel::Range("banana".into(), "cherry".into())),
        );
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn conformance_row_prefix() {
        assert_conformance(&sample(), &TableQuery::all().rows(KeySel::Prefix("b".into())));
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn conformance_col_range() {
        assert_conformance(
            &sample(),
            &TableQuery::all().cols(KeySel::Range("x1".into(), "x2".into())),
        );
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn conformance_col_prefix_with_row_keys() {
        assert_conformance(
            &sample(),
            &TableQuery::all()
                .rows(KeySel::keys(&["apple", "cherry", "nope"]))
                .cols(KeySel::Prefix("x".into())),
        );
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn conformance_empty_match() {
        assert_conformance(
            &sample(),
            &TableQuery::all().rows(KeySel::Range("zz".into(), "zzz".into())),
        );
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn conformance_limit() {
        assert_conformance(&sample(), &TableQuery::all().limit(3));
        assert_conformance(
            &sample(),
            &TableQuery::all().rows(KeySel::Prefix("b".into())).limit(1),
        );
    }

    /// Paged scan: pages respect `page_rows`, are row-disjoint, and
    /// concatenate to exactly the unpaged query result — on every engine.
    #[test]
    #[cfg_attr(miri, ignore)]
    fn scan_pages_cover_query() {
        let a = sample();
        let q = TableQuery::all().page_rows(2);
        for db in engines() {
            let t = db.bind("t", &BindOpts::default()).unwrap();
            t.put_assoc(&a).unwrap();
            let mut seen_rows = Vec::new();
            let mut nnz = 0usize;
            for page in t.scan(&q).unwrap() {
                let p = page.unwrap();
                assert!(p.row_keys().len() <= 2, "{:?}: page too tall", db.kind());
                seen_rows.extend(p.row_keys().to_vec());
                nnz += p.nnz();
            }
            let mut sorted = seen_rows.clone();
            sorted.sort();
            sorted.dedup();
            assert_eq!(seen_rows.len(), sorted.len(), "{:?}: rows overlap pages", db.kind());
            assert_eq!(nnz, a.nnz(), "{:?}", db.kind());
            let collected = t.scan(&q).unwrap().into_assoc().unwrap();
            assert_eq!(collected.triples(), a.triples(), "{:?}", db.kind());
        }
    }

    /// Scanning a string-valued table must not rewrite stored values:
    /// pages carry raw strings, and assembling them matches `query()` on
    /// every engine — even when a page's values all look numeric.
    #[test]
    #[cfg_attr(miri, ignore)]
    fn scan_string_table_matches_query() {
        let a = Assoc::from_str_triples(&[("r1", "c", "007"), ("r2", "c", "x")]);
        let q = TableQuery::all().page_rows(1); // the "007" row gets its own page
        for db in engines() {
            let t = db.bind("t", &BindOpts::default()).unwrap();
            t.put_assoc(&a).unwrap();
            let scanned = t.scan(&q).unwrap().into_assoc().unwrap();
            let queried = t.query(&q).unwrap();
            assert!(scanned.is_string_valued(), "{:?}", db.kind());
            assert_eq!(scanned.str_triples(), queried.str_triples(), "{:?}", db.kind());
            assert_eq!(scanned.get_str("r1", "c"), Some("007"), "{:?}", db.kind());
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn scan_respects_selector_and_limit() {
        let a = sample();
        let q = TableQuery::all().rows(KeySel::Prefix("b".into())).page_rows(1).limit(2);
        for db in engines() {
            let t = db.bind("t", &BindOpts::default()).unwrap();
            t.put_assoc(&a).unwrap();
            let got = t.scan(&q).unwrap().into_assoc().unwrap();
            let want = {
                let full = a.select_rows(&KeySel::Prefix("b".into()));
                let tr = full.triples();
                Assoc::from_triples(&tr[..2.min(tr.len())])
            };
            assert_eq!(want.triples(), got.triples(), "{:?}", db.kind());
        }
    }

    /// String-valued tables with selectors that make each engine scan a
    /// *different superset* (full row on Accumulo, coordinate window on
    /// SciDB, exact predicate on SQL) must still decode identically:
    /// value typing is inferred on the final result set, never on the
    /// scanned superset.
    #[test]
    #[cfg_attr(miri, ignore)]
    fn conformance_string_table_mixed_selectors() {
        let a = Assoc::from_str_triples(&[
            ("a", "c1", "7"),
            ("a", "c2", "x"),
            ("b", "c1", "y"),
        ]);
        let queries = vec![
            // final set all-numeric-looking -> numeric everywhere
            TableQuery::all().rows(KeySel::keys(&["a"])).cols(KeySel::keys(&["c1"])),
            // final set mixed -> string-valued everywhere
            TableQuery::all().rows(KeySel::keys(&["a"])),
            // scattered rows skipping the numeric-looking cell
            TableQuery::all().cols(KeySel::keys(&["c2"])),
        ];
        for q in &queries {
            let mut results: Vec<(DbKind, bool, Vec<(String, String, String)>)> = Vec::new();
            for db in engines() {
                let t = db.bind("t", &BindOpts::default()).unwrap();
                t.put_assoc(&a).unwrap();
                let got = t.query(q).unwrap();
                results.push((db.kind(), got.is_string_valued(), got.str_triples()));
            }
            let (k0, sv0, t0) = &results[0];
            for (k, sv, t) in &results[1..] {
                assert_eq!(sv0, sv, "{k0:?} vs {k:?} typing diverged on {q:?}");
                assert_eq!(t0, t, "{k0:?} vs {k:?} values diverged on {q:?}");
            }
        }
        // and the all-numeric-looking selection really decodes numeric
        let q = TableQuery::all().rows(KeySel::keys(&["a"])).cols(KeySel::keys(&["c1"]));
        for db in engines() {
            let t = db.bind("t", &BindOpts::default()).unwrap();
            t.put_assoc(&a).unwrap();
            let got = t.query(&q).unwrap();
            assert!(!got.is_string_valued(), "{:?}", db.kind());
            assert_eq!(got.get("a", "c1"), 7.0, "{:?}", db.kind());
        }
    }

    /// A bound-but-never-written table reads as empty on every engine,
    /// regardless of whether bind materialised storage eagerly.
    #[test]
    #[cfg_attr(miri, ignore)]
    fn conformance_bound_empty_table_reads() {
        for db in engines() {
            let t = db.bind("t", &BindOpts::default()).unwrap();
            assert_eq!(t.nnz().unwrap(), 0, "{:?}", db.kind());
            assert!(t.get_assoc().unwrap().is_empty(), "{:?}", db.kind());
            assert!(t.query(&TableQuery::all()).unwrap().is_empty(), "{:?}", db.kind());
            assert_eq!(t.scan(&TableQuery::all()).unwrap().count(), 0, "{:?}", db.kind());
        }
    }

    /// `put_assoc` replaces previous contents identically on all engines.
    #[test]
    #[cfg_attr(miri, ignore)]
    fn conformance_put_replaces() {
        let a1 = Assoc::from_triples(&[("x", "y", 1.0), ("p", "q", 2.0)]);
        let a2 = Assoc::from_triples(&[("p", "q", 9.0)]);
        for db in engines() {
            let t = db.bind("t", &BindOpts::default()).unwrap();
            t.put_assoc(&a1).unwrap();
            t.put_assoc(&a2).unwrap();
            let got = t.get_assoc().unwrap();
            assert_eq!(a2.triples(), got.triples(), "{:?}", db.kind());
            assert_eq!(t.nnz().unwrap(), 1, "{:?}", db.kind());
        }
    }

    /// `ls`/`exists` enumerate logical tables only — the key-value
    /// engine's `_T`/`_Deg` companions stay hidden.
    #[test]
    #[cfg_attr(miri, ignore)]
    fn ls_hides_companion_tables() {
        let db = AccumuloConnector::new();
        let t = DbServer::bind(&db, "t", &BindOpts::default()).unwrap();
        t.put_assoc(&sample()).unwrap();
        assert_eq!(DbServer::ls(&db), vec!["t".to_string()]);
        assert!(!db.exists("t_T"));
        // the physical schema tables are still there underneath
        assert_eq!(db.store().list_tables().len(), 3);
    }

    /// The key-value engine's `_T`/`_Deg` schema reservation is enforced
    /// at bind time, in both directions.
    #[test]
    #[cfg_attr(miri, ignore)]
    fn bind_rejects_companion_namespace_collisions() {
        let db = AccumuloConnector::new();
        DbServer::bind(&db, "foo", &BindOpts::default()).unwrap();
        assert!(DbServer::bind(&db, "foo_T", &BindOpts::default()).is_err());
        assert!(DbServer::bind(&db, "foo_Deg", &BindOpts::default()).is_err());
        // a suffix-shaped name with no base table is a legal logical table
        let t = DbServer::bind(&db, "data_T", &BindOpts::default()).unwrap();
        t.put_assoc(&sample()).unwrap();
        assert!(db.exists("data_T"));
        // reverse: binding must not adopt a pre-existing independent
        // table as its schema companion
        let db2 = AccumuloConnector::new();
        DbServer::bind(&db2, "bar_T", &BindOpts::default()).unwrap();
        assert!(DbServer::bind(&db2, "bar", &BindOpts::default()).is_err());
    }

    /// The `DBserver` namespace surface on all engines.
    #[test]
    #[cfg_attr(miri, ignore)]
    fn server_namespace_ops() {
        let a = sample();
        for db in engines() {
            let t = db.bind("obj", &BindOpts::default()).unwrap();
            assert_eq!(t.name(), "obj");
            t.put_assoc(&a).unwrap();
            assert!(db.exists("obj"), "{:?}", db.kind());
            assert_eq!(t.nnz().unwrap(), a.nnz(), "{:?}", db.kind());
            db.delete_table("obj").unwrap();
            assert!(!db.exists("obj"), "{:?}", db.kind());
            assert!(db.delete_table("obj").is_err(), "{:?}", db.kind());
        }
    }

    /// Cross-engine translation through the unified API: Accumulo ->
    /// Assoc -> SciDB -> Assoc -> SQL -> Assoc preserves numeric triples
    /// (the D4M claim that "the associative array model allows translation
    /// of data between Accumulo, SciDB and PostGRES") — generically, with
    /// no engine-specific calls.
    #[test]
    #[cfg_attr(miri, ignore)]
    fn cross_engine_roundtrip() {
        let a = Assoc::from_triples(&[
            ("v001", "v002", 1.0),
            ("v001", "v003", 2.0),
            ("v002", "v003", 3.0),
        ]);
        let mut carried = a.clone();
        for db in engines() {
            let t = db.bind("edges", &BindOpts::default()).unwrap();
            t.put_assoc(&carried).unwrap();
            carried = t.get_assoc().unwrap();
            assert_eq!(a.triples(), carried.triples(), "{:?} leg diverged", db.kind());
        }
    }

    /// Same chain for a string-valued (non-numeric) assoc: SciDB carries
    /// the value dictionary, SQL a TEXT column, Accumulo raw values.
    #[test]
    #[cfg_attr(miri, ignore)]
    fn cross_engine_roundtrip_strings() {
        let a = Assoc::from_str_triples(&[
            ("doc1", "word|cat", "3x"),
            ("doc2", "word|dog", "1x"),
            ("doc2", "word|cat", "7x"),
        ]);
        let mut carried = a.clone();
        for db in engines() {
            let t = db.bind("txt", &BindOpts::default()).unwrap();
            t.put_assoc(&carried).unwrap();
            carried = t.get_assoc().unwrap();
            assert!(carried.is_string_valued(), "{:?} dropped string values", db.kind());
            assert_eq!(a.str_triples(), carried.str_triples(), "{:?} leg diverged", db.kind());
        }
        // and a pushed-down prefix query on the string table agrees too
        let q = TableQuery::all().cols(KeySel::Prefix("word|c".into()));
        let want = a.select_cols(&KeySel::Prefix("word|c".into()));
        for db in engines() {
            let t = db.bind("txt", &BindOpts::default()).unwrap();
            t.put_assoc(&a).unwrap();
            let got = t.query(&q).unwrap();
            assert_eq!(want.str_triples(), got.str_triples(), "{:?}", db.kind());
        }
    }
}
