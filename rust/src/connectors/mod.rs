//! D4M database connectors — the `DB()` / `T = DB('table')` surface of
//! the paper (Figure 1: "D4M server bindings leverage various database
//! connectors").
//!
//! One facade, three engines:
//! * [`accumulo::AccumuloConnector`] — key-value tables in the D4M 2.0
//!   schema (Tedge / TedgeT / TedgeDeg / TedgeTxt).
//! * [`scidb::SciDbConnector`] — chunked arrays with in-store ops.
//! * [`sql::SqlConnector`] — relational triple tables.
//!
//! Every connector speaks [`crate::assoc::Assoc`] in both directions,
//! which is what makes cross-engine translation (the BigDAWG text-island
//! role, [`crate::polystore`]) a pair of connector calls.

pub mod accumulo;
pub mod scidb;
pub mod sql;

pub use accumulo::{AccumuloConnector, D4mTable, D4mTableConfig};
pub use scidb::SciDbConnector;
pub use sql::SqlConnector;

/// Which engine a D4M binding points at (the `DBserver` type tag).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DbKind {
    Accumulo,
    SciDb,
    Sql,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assoc::Assoc;

    /// Cross-engine translation: Accumulo -> Assoc -> SciDB -> Assoc ->
    /// SQL -> Assoc must preserve the numeric triples (the D4M claim that
    /// "the associative array model allows translation of data between
    /// Accumulo, SciDB and PostGRES").
    #[test]
    fn cross_engine_roundtrip() {
        let a = Assoc::from_triples(&[
            ("v001", "v002", 1.0),
            ("v001", "v003", 2.0),
            ("v002", "v003", 3.0),
        ]);

        // Accumulo leg
        let acc = AccumuloConnector::new();
        let t = acc.bind("edges", &D4mTableConfig::default()).unwrap();
        t.put_assoc(&a).unwrap();
        let a1 = t.get_assoc().unwrap();
        assert_eq!(a.triples(), a1.triples());

        // SciDB leg
        let scidb = SciDbConnector::new();
        scidb.put_assoc("edges_arr", &a1, 64).unwrap();
        let a2 = scidb.get_assoc("edges_arr").unwrap();
        assert_eq!(a.triples(), a2.triples());

        // SQL leg
        let sqldb = SqlConnector::new();
        sqldb.put_assoc("edges_rel", &a2).unwrap();
        let a3 = sqldb.get_assoc("edges_rel").unwrap();
        assert_eq!(a.triples(), a3.triples());
    }
}
