//! SQL connector (PostGRES/MySQL stand-in): stores an assoc as a
//! `(row_key TEXT, col_key TEXT, val FLOAT | val_txt TEXT)` triple table —
//! the natural relational projection of an associative array — and reads
//! it back, optionally through WHERE predicates pushed into the engine.
//!
//! Implements the unified [`DbServer`]/[`DbTable`] binding surface:
//! [`TableQuery`] selectors are lowered to WHERE predicates on the
//! `row_key`/`col_key` columns, evaluated inside the engine.

use std::sync::Arc;

use crate::assoc::Assoc;
use crate::error::Result;
use crate::relational::{ColType, Predicate, RelDb, RelTable, Row, SqlValue, TableSchema};

use super::api::{self, AssocPages, BindOpts, DbServer, DbTable, TableQuery};
use super::DbKind;

/// The SQL-engine connector (owns the embedded relational database).
/// Cloning is cheap and shares the database.
#[derive(Clone)]
pub struct SqlConnector {
    db: Arc<RelDb>,
}

impl Default for SqlConnector {
    fn default() -> Self {
        Self::new()
    }
}

impl SqlConnector {
    pub fn new() -> Self {
        SqlConnector { db: Arc::new(RelDb::new()) }
    }

    pub fn db(&self) -> &RelDb {
        &self.db
    }

    /// Store an assoc as a triple table. Numeric assocs use a FLOAT value
    /// column; string-valued assocs a TEXT one.
    pub fn put_assoc(&self, name: &str, a: &Assoc) -> Result<Arc<RelTable>> {
        let schema = if a.is_string_valued() {
            TableSchema::new(
                name,
                &[("row_key", ColType::Text), ("col_key", ColType::Text), ("val_txt", ColType::Text)],
            )
        } else {
            TableSchema::new(
                name,
                &[("row_key", ColType::Text), ("col_key", ColType::Text), ("val", ColType::Float)],
            )
        };
        let t = self.db.create_table(schema)?;
        let rows: Vec<Vec<SqlValue>> = if a.is_string_valued() {
            a.str_triples()
                .into_iter()
                .map(|(r, c, v)| {
                    vec![SqlValue::Text(r), SqlValue::Text(c), SqlValue::Text(v)]
                })
                .collect()
        } else {
            a.triples()
                .into_iter()
                .map(|(r, c, v)| vec![SqlValue::Text(r), SqlValue::Text(c), SqlValue::Float(v)])
                .collect()
        };
        t.insert_batch(rows)?;
        // equality index over the row keys: paged scans answer each page
        // through it instead of a full-table predicate pass (built after
        // the bulk insert, one pass)
        t.create_index("row_key")?;
        Ok(t)
    }

    /// Read a triple table back as an assoc.
    pub fn get_assoc(&self, name: &str) -> Result<Assoc> {
        self.get_assoc_where(name, None)
    }

    /// Read with a WHERE predicate evaluated inside the engine.
    pub fn get_assoc_where(&self, name: &str, pred: Option<&Predicate>) -> Result<Assoc> {
        select_to_assoc(&self.db.table_or_err(name)?, pred)
    }
}

/// Render triple-table rows as raw string triples (TEXT tables keep
/// stored values verbatim; FLOAT tables render the number).
fn rows_to_raw_triples(is_text: bool, rows: Vec<Row>) -> Vec<(String, String, String)> {
    rows.into_iter()
        .map(|r| {
            let row = r[0].as_text().unwrap_or("").to_string();
            let col = r[1].as_text().unwrap_or("").to_string();
            let val = if is_text {
                r[2].as_text().unwrap_or("").to_string()
            } else {
                crate::assoc::io::fmt_num(r[2].as_f64().unwrap_or(0.0))
            };
            (row, col, val)
        })
        .collect()
}

/// SELECT through `pred` on one pinned table handle, as raw string
/// triples.
fn select_to_raw_triples(
    t: &RelTable,
    pred: Option<&Predicate>,
) -> Result<Vec<(String, String, String)>> {
    let is_text = t.schema.col_index("val_txt").is_some();
    Ok(rows_to_raw_triples(is_text, t.select(None, pred, None)?))
}

/// SELECT + decode into an assoc (numeric when every value parses).
fn select_to_assoc(t: &RelTable, pred: Option<&Predicate>) -> Result<Assoc> {
    crate::assoc::io::parse_triples(select_to_raw_triples(t, pred)?)
}

/// `T(r, c)` against a triple table: selectors become a WHERE predicate
/// on the key columns, evaluated inside the engine.
fn sql_query(conn: &SqlConnector, name: &str, q: &TableQuery) -> Result<Assoc> {
    let t = match conn.db.table(name) {
        Some(t) => t,
        None => return Ok(Assoc::empty()), // bound but never written
    };
    let row_sel = q.rows.clone();
    let col_sel = q.cols.clone();
    let pred: Predicate = Box::new(move |r: &Row| {
        row_sel.matches(r[0].as_text().unwrap_or(""))
            && col_sel.matches(r[1].as_text().unwrap_or(""))
    });
    let a = select_to_assoc(&t, Some(&pred))?;
    Ok(api::finish(a, q))
}

/// A bound triple table (created lazily at first `put_assoc`, since the
/// value column type depends on the assoc).
pub struct SqlTable {
    name: String,
    conn: SqlConnector,
}

impl DbTable for SqlTable {
    fn name(&self) -> &str {
        &self.name
    }

    fn put_assoc(&self, a: &Assoc) -> Result<()> {
        // create-once storage: replace previous contents (unconditional
        // drop — no exists-then-drop window for a racing writer to hit)
        let _ = self.conn.db.drop_table(&self.name);
        self.conn.put_assoc(&self.name, a).map(|_| ())
    }

    fn get_assoc(&self) -> Result<Assoc> {
        match self.conn.db.table(&self.name) {
            Some(t) => select_to_assoc(&t, None),
            None => Ok(Assoc::empty()), // bound but never written
        }
    }

    fn nnz(&self) -> Result<usize> {
        Ok(self.conn.db.table(&self.name).map(|t| t.count()).unwrap_or(0))
    }

    fn query(&self, q: &TableQuery) -> Result<Assoc> {
        sql_query(&self.conn, &self.name, q)
    }

    fn scan(&self, q: &TableQuery) -> Result<AssocPages> {
        // pin one table generation (put_assoc swaps the table handle on
        // replace); the row-key snapshot reads the equality index's
        // distinct keys — no projected full-table SELECT
        let t = match self.conn.db.table(&self.name) {
            Some(t) => t,
            None => return Ok(api::empty_pages(q)), // bound but never written
        };
        let rows: Vec<String> = match t.index_keys("row_key") {
            Some(keys) => keys.into_iter().filter(|k| q.rows.matches(k)).collect(),
            None => t
                .select(Some(&["row_key"]), None, None)?
                .iter()
                .filter_map(|r| r[0].as_text())
                .filter(|k| q.rows.matches(k))
                .map(str::to_string)
                .collect(),
        };
        let col_sel = q.cols.clone();
        let fetch = Box::new(move |page: &[String]| {
            let is_text = t.schema.col_index("val_txt").is_some();
            // each page is answered by index point lookups; the predicate
            // full-scan only remains as a fallback for un-indexed tables
            let page_rows: Vec<Row> = if t.has_index("row_key") {
                t.select_by_key("row_key", page)?
            } else {
                let keys: std::collections::HashSet<String> = page.iter().cloned().collect();
                let pred: Predicate = Box::new(move |r: &Row| {
                    r[0].as_text().map(|k| keys.contains(k)).unwrap_or(false)
                });
                t.select(None, Some(&pred), None)?
            };
            let kept: Vec<Row> = page_rows
                .into_iter()
                .filter(|r| col_sel.matches(r[1].as_text().unwrap_or("")))
                .collect();
            // both selectors applied exactly; build a raw page — no
            // numeric inference on stored values
            Ok(Assoc::from_str_triples(&rows_to_raw_triples(is_text, kept)))
        });
        Ok(AssocPages::over_rows(rows, q.page_rows, q.limit, fetch))
    }
}

impl DbServer for SqlConnector {
    fn kind(&self) -> DbKind {
        DbKind::Sql
    }

    fn ls(&self) -> Vec<String> {
        self.db.list()
    }

    fn delete_table(&self, name: &str) -> Result<()> {
        self.db.drop_table(name)
    }

    fn bind(&self, name: &str, _opts: &BindOpts) -> Result<Box<dyn DbTable>> {
        Ok(Box::new(SqlTable { name: name.to_string(), conn: self.clone() }))
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    #[cfg_attr(miri, ignore)]
    fn numeric_roundtrip() {
        let c = SqlConnector::new();
        let a = Assoc::from_triples(&[("r1", "c1", 1.5), ("r2", "c2", -2.0)]);
        c.put_assoc("t", &a).unwrap();
        assert_eq!(c.get_assoc("t").unwrap(), a);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn string_roundtrip() {
        let c = SqlConnector::new();
        let a = Assoc::from_str_triples(&[("r", "c", "hello")]);
        c.put_assoc("t", &a).unwrap();
        let b = c.get_assoc("t").unwrap();
        assert_eq!(b.get_str("r", "c"), Some("hello"));
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn where_pushdown() {
        let c = SqlConnector::new();
        let a = Assoc::from_triples(&[("r1", "c1", 1.0), ("r2", "c2", 10.0)]);
        c.put_assoc("t", &a).unwrap();
        let pred: Predicate = Box::new(|row| row[2].as_f64().unwrap_or(0.0) > 5.0);
        let b = c.get_assoc_where("t", Some(&pred)).unwrap();
        assert_eq!(b.nnz(), 1);
        assert_eq!(b.get("r2", "c2"), 10.0);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn put_assoc_builds_row_key_index() {
        let c = SqlConnector::new();
        c.put_assoc("t", &Assoc::from_triples(&[("r1", "c1", 1.0), ("r2", "c1", 2.0)]))
            .unwrap();
        let t = c.db().table_or_err("t").unwrap();
        assert!(t.has_index("row_key"));
        assert_eq!(t.select_by_key("row_key", &["r2".to_string()]).unwrap().len(), 1);
        let mut keys = t.index_keys("row_key").unwrap();
        keys.sort();
        assert_eq!(keys, vec!["r1".to_string(), "r2".to_string()]);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn missing_table_errors() {
        let c = SqlConnector::new();
        assert!(c.get_assoc("nope").is_err());
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn rebind_put_replaces_contents() {
        let c = SqlConnector::new();
        let t = c.bind("t", &BindOpts::default()).unwrap();
        t.put_assoc(&Assoc::from_triples(&[("a", "b", 1.0)])).unwrap();
        t.put_assoc(&Assoc::from_str_triples(&[("x", "y", "z")])).unwrap();
        let back = t.get_assoc().unwrap();
        assert!(back.is_string_valued());
        assert_eq!(back.get_str("x", "y"), Some("z"));
    }
}
