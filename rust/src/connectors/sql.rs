//! SQL connector (PostGRES/MySQL stand-in): stores an assoc as a
//! `(row_key TEXT, col_key TEXT, val FLOAT | val_txt TEXT)` triple table —
//! the natural relational projection of an associative array — and reads
//! it back, optionally through WHERE predicates pushed into the engine.

use std::sync::Arc;

use crate::assoc::Assoc;
use crate::error::Result;
use crate::relational::{ColType, Predicate, RelDb, RelTable, SqlValue, TableSchema};

/// The SQL-engine connector (owns the embedded relational database).
pub struct SqlConnector {
    db: RelDb,
}

impl Default for SqlConnector {
    fn default() -> Self {
        Self::new()
    }
}

impl SqlConnector {
    pub fn new() -> Self {
        SqlConnector { db: RelDb::new() }
    }

    pub fn db(&self) -> &RelDb {
        &self.db
    }

    /// Store an assoc as a triple table. Numeric assocs use a FLOAT value
    /// column; string-valued assocs a TEXT one.
    pub fn put_assoc(&self, name: &str, a: &Assoc) -> Result<Arc<RelTable>> {
        let schema = if a.is_string_valued() {
            TableSchema::new(
                name,
                &[("row_key", ColType::Text), ("col_key", ColType::Text), ("val_txt", ColType::Text)],
            )
        } else {
            TableSchema::new(
                name,
                &[("row_key", ColType::Text), ("col_key", ColType::Text), ("val", ColType::Float)],
            )
        };
        let t = self.db.create_table(schema)?;
        let rows: Vec<Vec<SqlValue>> = if a.is_string_valued() {
            a.str_triples()
                .into_iter()
                .map(|(r, c, v)| {
                    vec![SqlValue::Text(r), SqlValue::Text(c), SqlValue::Text(v)]
                })
                .collect()
        } else {
            a.triples()
                .into_iter()
                .map(|(r, c, v)| vec![SqlValue::Text(r), SqlValue::Text(c), SqlValue::Float(v)])
                .collect()
        };
        t.insert_batch(rows)?;
        Ok(t)
    }

    /// Read a triple table back as an assoc.
    pub fn get_assoc(&self, name: &str) -> Result<Assoc> {
        self.get_assoc_where(name, None)
    }

    /// Read with a WHERE predicate evaluated inside the engine.
    pub fn get_assoc_where(&self, name: &str, pred: Option<&Predicate>) -> Result<Assoc> {
        let t = self.db.table_or_err(name)?;
        let is_text = t.schema.col_index("val_txt").is_some();
        let rows = t.select(None, pred, None)?;
        let triples: Vec<(String, String, String)> = rows
            .into_iter()
            .map(|r| {
                let row = r[0].as_text().unwrap_or("").to_string();
                let col = r[1].as_text().unwrap_or("").to_string();
                let val = if is_text {
                    r[2].as_text().unwrap_or("").to_string()
                } else {
                    crate::assoc::io::fmt_num(r[2].as_f64().unwrap_or(0.0))
                };
                (row, col, val)
            })
            .collect();
        crate::assoc::io::parse_triples(triples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numeric_roundtrip() {
        let c = SqlConnector::new();
        let a = Assoc::from_triples(&[("r1", "c1", 1.5), ("r2", "c2", -2.0)]);
        c.put_assoc("t", &a).unwrap();
        assert_eq!(c.get_assoc("t").unwrap(), a);
    }

    #[test]
    fn string_roundtrip() {
        let c = SqlConnector::new();
        let a = Assoc::from_str_triples(&[("r", "c", "hello")]);
        c.put_assoc("t", &a).unwrap();
        let b = c.get_assoc("t").unwrap();
        assert_eq!(b.get_str("r", "c"), Some("hello"));
    }

    #[test]
    fn where_pushdown() {
        let c = SqlConnector::new();
        let a = Assoc::from_triples(&[("r1", "c1", 1.0), ("r2", "c2", 10.0)]);
        c.put_assoc("t", &a).unwrap();
        let pred: Predicate = Box::new(|row| row[2].as_f64().unwrap_or(0.0) > 5.0);
        let b = c.get_assoc_where("t", Some(&pred)).unwrap();
        assert_eq!(b.nnz(), 1);
        assert_eq!(b.get("r2", "c2"), 10.0);
    }

    #[test]
    fn missing_table_errors() {
        let c = SqlConnector::new();
        assert!(c.get_assoc("nope").is_err());
    }
}
