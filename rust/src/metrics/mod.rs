//! Lightweight metrics used by the pipeline, kvstore and coordinator:
//! atomic counters, rate meters and log-scale latency histograms.

pub mod names;

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Monotonic atomic counter.
#[derive(Debug, Default)]
pub struct Counter {
    n: AtomicU64,
}

impl Counter {
    pub fn new() -> Self {
        Counter { n: AtomicU64::new(0) }
    }

    pub fn add(&self, v: u64) {
        self.n.fetch_add(v, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.n.load(Ordering::Relaxed)
    }
}

/// Events-per-second meter over a wall-clock window started at `start()`.
#[derive(Debug)]
pub struct RateMeter {
    count: Counter,
    start: Instant,
}

impl Default for RateMeter {
    fn default() -> Self {
        Self::new()
    }
}

impl RateMeter {
    pub fn new() -> Self {
        RateMeter { count: Counter::new(), start: Instant::now() }
    }

    pub fn add(&self, v: u64) {
        self.count.add(v);
    }

    pub fn count(&self) -> u64 {
        self.count.get()
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Events per second since construction.
    pub fn rate(&self) -> f64 {
        let secs = self.elapsed().as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.count.get() as f64 / secs
        }
    }
}

/// Power-of-two bucketed histogram of nanosecond latencies.
/// Lock-free recording; buckets `[2^i, 2^{i+1})` ns for i in 0..64.
///
/// Besides latencies, the histogram tracks the instants of its first and
/// last samples, so per-op rates are derived from the op's **own active
/// span** — not from how long the process has been alive. (The old
/// behaviour divided each op's count by the server-lifetime clock, which
/// made any op exercised early read as permanently slow.)
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    sum_ns: AtomicU64,
    count: AtomicU64,
    /// Anchor for the sample-instant atomics below.
    created: Instant,
    /// Nanos since `created` of the first sample (`u64::MAX` = none yet).
    first_ns: AtomicU64,
    /// Nanos since `created` of the last sample.
    last_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: (0..64).map(|_| AtomicU64::new(0)).collect(),
            sum_ns: AtomicU64::new(0),
            count: AtomicU64::new(0),
            created: Instant::now(),
            first_ns: AtomicU64::new(u64::MAX),
            last_ns: AtomicU64::new(0),
        }
    }

    pub fn record(&self, d: Duration) {
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        let idx = (64 - ns.max(1).leading_zeros() as usize).saturating_sub(1);
        self.buckets[idx.min(63)].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        // sample instant (completion time); min/max keep the true first
        // and last under concurrent recording
        let at = self.created.elapsed().as_nanos().min((u64::MAX - 1) as u128) as u64;
        self.first_ns.fetch_min(at, Ordering::Relaxed);
        self.last_ns.fetch_max(at, Ordering::Relaxed);
    }

    /// Wall-clock span between the first and last recorded samples
    /// (zero until two samples exist).
    pub fn span(&self) -> Duration {
        let first = self.first_ns.load(Ordering::Relaxed);
        if first == u64::MAX {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.last_ns.load(Ordering::Relaxed).saturating_sub(first))
    }

    /// Ops per second over this op's own active window: the
    /// first-to-last-sample span widened by one mean latency (covering
    /// the first sample's execution, and making the single-sample rate
    /// `1 / latency` instead of undefined).
    pub fn rate_per_sec(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        let window = self.span().as_secs_f64() + self.mean_ns() / 1e9;
        c as f64 / window.max(1e-9)
    }

    /// Time a closure, recording its latency.
    pub fn time<T>(&self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(t0.elapsed());
        out
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_ns(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_ns.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Approximate quantile from bucket boundaries (upper bound of the
    /// bucket containing the q-th sample).
    pub fn quantile_ns(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * q).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1).min(63);
            }
        }
        u64::MAX
    }
}

/// A named snapshot of pipeline/coordinator metrics, for reporting.
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    pub name: String,
    pub count: u64,
    pub rate_per_sec: f64,
    pub mean_latency_ns: f64,
    pub p99_latency_ns: u64,
}

impl std::fmt::Display for Snapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<24} n={:<12} rate={:<14} mean={:.1}us p99={:.1}us",
            self.name,
            self.count,
            crate::util::fmt_rate(self.rate_per_sec),
            self.mean_latency_ns / 1e3,
            self.p99_latency_ns as f64 / 1e3,
        )
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn counter_adds() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn counter_concurrent() {
        let c = std::sync::Arc::new(Counter::new());
        let hs: Vec<_> = (0..8)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 8000);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn rate_meter_counts() {
        let m = RateMeter::new();
        m.add(100);
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(m.count(), 100);
        assert!(m.rate() > 0.0);
    }

    #[test]
    fn histogram_mean_and_quantile() {
        let h = Histogram::new();
        for _ in 0..100 {
            h.record(Duration::from_nanos(1000));
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean_ns() - 1000.0).abs() < 1.0);
        // 1000ns lives in bucket [512, 1024) -> upper bound 1024
        assert_eq!(h.quantile_ns(0.5), 1024);
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        assert_eq!(h.mean_ns(), 0.0);
        assert_eq!(h.quantile_ns(0.99), 0);
        assert_eq!(h.rate_per_sec(), 0.0);
        assert_eq!(h.span(), Duration::ZERO);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn rate_uses_op_span_not_process_lifetime() {
        let h = Histogram::new();
        // idle "server lifetime" before the op is first exercised
        std::thread::sleep(Duration::from_millis(50));
        for _ in 0..10 {
            h.record(Duration::from_micros(100));
        }
        // old behaviour: 10 ops / ≥50 ms lifetime ≈ ≤200/s forever.
        // new behaviour: the burst's own window is its microsecond span
        // plus one 100 µs mean latency, so the rate lands in the tens of
        // thousands — the idle prefix no longer dilutes it.
        // (threshold leaves headroom for scheduler jitter in the burst:
        // the old computation cannot exceed 10 / 50 ms = 200/s here)
        assert!(
            h.rate_per_sec() > 400.0,
            "rate {} diluted by process lifetime",
            h.rate_per_sec()
        );
    }

    #[test]
    fn rate_single_sample_is_inverse_latency() {
        let h = Histogram::new();
        h.record(Duration::from_millis(10));
        let r = h.rate_per_sec();
        assert!((50.0..200.0).contains(&r), "rate {r} should be ~100/s");
    }

    #[test]
    fn histogram_time_returns_value() {
        let h = Histogram::new();
        let x = h.time(|| 7);
        assert_eq!(x, 7);
        assert_eq!(h.count(), 1);
    }
}
