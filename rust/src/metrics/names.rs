//! The counter/histogram name registry: every fixed metric name the
//! server or client exports is declared here exactly once.
//!
//! Names follow the `segment.segment` grammar with the first segment
//! naming the owning subsystem — one of `net`, `kernels`, `plan`,
//! `storage`, `client`. `tools/d4m-verify`'s counter pass enforces both
//! rules: a name declared twice, a name violating the grammar, or a
//! counter-shaped string literal at a stats-assembly site that does not
//! appear here is a CI failure. Per-op latency histograms (keyed by
//! request op like `query` or `ingest`) are single-segment dynamic names
//! and intentionally live outside this registry.

// --------------------------------------------------------------- net.*

/// Request histogram: every decoded client request.
pub const NET_REQUESTS: &str = "net.requests";
/// Frames that failed magic/version/length validation or decode.
pub const NET_BAD_FRAMES: &str = "net.bad_frames";
/// Bytes read off accepted connections (header + payload).
pub const NET_BYTES_IN: &str = "net.bytes_in";
/// Bytes written to accepted connections (header + payload).
pub const NET_BYTES_OUT: &str = "net.bytes_out";
/// Currently-open server-side scan cursors (gauge).
pub const NET_CURSORS_OPEN: &str = "net.cursors_open";
/// Cursors reaped by the background sweep after the grace window.
pub const NET_CURSORS_REAPED: &str = "net.cursors_reaped";
/// Cursors parked when their connection died (resume-grace window).
pub const NET_CURSORS_ORPHANED: &str = "net.cursors_orphaned";
/// Connections shed with a typed Overloaded error under pool pressure.
pub const NET_SHEDS: &str = "net.sheds";

// ----------------------------------------------------------- kernels.*

/// Algebra kernel invocations that took the parallel path.
pub const KERNELS_PARALLEL_OPS: &str = "kernels.parallel_ops";
/// Algebra kernel invocations that stayed serial (below threshold).
pub const KERNELS_SERIAL_OPS: &str = "kernels.serial_ops";
/// Rows processed through the blocked SpGEMM row partitioner.
pub const KERNELS_BLOCKED_ROWS: &str = "kernels.blocked_rows";

// -------------------------------------------------------------- plan.*

/// Plan ops executed by the streaming plan executor.
pub const PLAN_OPS: &str = "plan.ops";
/// Select ops folded into their source scan's pushdown query.
pub const PLAN_FUSED_SELECTS: &str = "plan.fused_selects";
/// Reduce ops fused with a pending matmul (product never built).
pub const PLAN_FUSED_REDUCES: &str = "plan.fused_reduces";
/// Materialised non-result intermediate values.
pub const PLAN_INTERMEDIATES: &str = "plan.intermediates";

// ----------------------------------------------------------- storage.*

/// Bytes appended to write-ahead logs (record header + payload).
pub const STORAGE_WAL_BYTES_APPENDED: &str = "storage.wal_bytes_appended";
/// WAL fsync calls (group-commit cadence).
pub const STORAGE_WAL_FSYNCS: &str = "storage.wal_fsyncs";
/// Memtable flushes frozen into on-disk runs.
pub const STORAGE_FLUSHES: &str = "storage.flushes";
/// Background compactions completed.
pub const STORAGE_COMPACTIONS: &str = "storage.compactions";
/// Writer stalls waiting for the compaction backlog to drain.
pub const STORAGE_BACKPRESSURE_STALLS: &str = "storage.backpressure_stalls";

// ------------------------------------------------------------ client.*

/// Requests retried by the self-healing client.
pub const CLIENT_RETRIES: &str = "client.retries";
/// Reconnects performed by the self-healing client.
pub const CLIENT_RECONNECTS: &str = "client.reconnects";
/// Cursors re-attached via a resume token after a reconnect.
pub const CLIENT_CURSOR_RESUMES: &str = "client.cursor_resumes";
