//! D4M CLI — the leader entrypoint: drives the coordinator over the
//! embedded engines. Hand-rolled argument parsing (no clap in the
//! offline vendor set).
//!
//! ```text
//! d4m demo                          quickstart associative-array tour
//! d4m ingest  [--scale S] [--workers W] [--batch B]   pipeline ingest bench
//! d4m tablemult [--scale S] [--mode server|client|dense]
//! d4m bfs     [--scale S] [--hops H]
//! d4m jaccard [--scale S]
//! d4m ktruss  [--scale S] [--k K]
//! d4m tables                        list tables after a demo ingest
//! d4m serve   [--addr H:P] [--max-conns N] [--workers N]
//!             [--kernel-threads N] [--data-dir DIR] [--flush-bytes N]
//!                                   network front-end
//!                                   (runs until a client sends
//!                                   shutdown); --data-dir turns on the
//!                                   durable engine: WAL + on-disk runs,
//!                                   crash recovery on restart
//! d4m client <ping|tables|quickstart|query|plan|scan4|scan-pages|
//!             pipeline-bench|ingest-batches|verify-batches|stats|
//!             shutdown> [--addr H:P]
//!                                   drive a remote d4m serve (typed ops
//!                                   self-heal: retries with backoff,
//!                                   reconnect, cursor resume);
//!                                   `query T --rows SEL --cols SEL`
//!                                   pushes selectors server-side, and
//!                                   `plan '<expr>'` compiles a whole
//!                                   expression (e.g. "sum(A('r1,:,r9,',
//!                                   ':') * B, 2)") to one server-side
//!                                   round trip
//! d4m chaos   --upstream H:P [--listen H:P] [--seed N]
//!             [--profile drop|delay|corrupt|mixed|none] [--rate F]
//!             [--delay-ms N]        fault-injection proxy in front of a
//!                                   d4m serve (runs until killed)
//! ```

// unwrap/expect are disallowed repo-wide (clippy.toml); this module's
// call sites predate the policy and are tracked for burn-down in
// EXPERIMENTS.md — never-panic modules carry no such allow.
#![allow(clippy::disallowed_methods)]
use std::collections::{HashMap, VecDeque};
use std::sync::Arc;
use std::time::Duration;

use d4m::assoc::{io::display_full, Assoc, KeySel};
use d4m::connectors::TableQuery;
use d4m::coordinator::{D4mApi, D4mServer, ExecHint, MultDest, Request, Response};
use d4m::gen::{kronecker_triples, KroneckerParams};
use d4m::kvstore::{KvStore, StorageConfig, TabletConfig};
use d4m::net::{ChaosOpts, ChaosProxy, NetOpts, Profile, RemoteD4m, RetryPolicy};
use d4m::pipeline::PipelineConfig;
use d4m::util::{fmt_rate, parse_keysel};

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            let val = args.get(i + 1).cloned().unwrap_or_default();
            out.insert(name.to_string(), val);
            i += 2;
        } else {
            i += 1;
        }
    }
    out
}

fn flag<T: std::str::FromStr>(flags: &HashMap<String, String>, name: &str, default: T) -> T {
    flags.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn ingest_kronecker(server: &D4mServer, scale: u32, workers: usize, batch: usize) -> u64 {
    let triples = kronecker_triples(&KroneckerParams::new(scale, 16, 20170710));
    let n = triples.len() as u64;
    let rep = server
        .handle(Request::Ingest {
            table: "G".into(),
            triples,
            pipeline: PipelineConfig {
                num_workers: workers,
                batch_size: batch,
                ..Default::default()
            },
        })
        .expect("ingest failed");
    if let Response::Ingested(r) = rep {
        println!("ingest: {r}");
    }
    n
}

fn cmd_demo() {
    println!("== D4M 3.0 quickstart ==");
    let a = Assoc::from_triples(&[
        ("alice", "carol", 1.0),
        ("alice", "bob", 1.0),
        ("bob", "carol", 2.0),
    ]);
    println!("A =\n{}", display_full(&a));
    println!("A' =\n{}", display_full(&a.transpose()));
    println!("A' * A =\n{}", display_full(&a.transpose().matmul(&a)));
    let deg = a.sum(1);
    println!("column degrees =\n{}", display_full(&deg));
}

fn cmd_ingest(flags: HashMap<String, String>) {
    let scale: u32 = flag(&flags, "scale", 12);
    let workers: usize = flag(&flags, "workers", 4);
    let batch: usize = flag(&flags, "batch", 2048);
    let server = D4mServer::new();
    println!("kronecker SCALE={scale} ef=16, {workers} workers, batch {batch}");
    ingest_kronecker(&server, scale, workers, batch);
    for s in server.snapshots() {
        println!("{s}");
    }
}

fn cmd_tablemult(flags: HashMap<String, String>) {
    let scale: u32 = flag(&flags, "scale", 10);
    let mode: String = flag(&flags, "mode", "server".to_string());
    let server = D4mServer::new();
    let edges = ingest_kronecker(&server, scale, 4, 4096);
    let t0 = std::time::Instant::now();
    match mode.as_str() {
        "server" => {
            let r = server
                .handle(Request::TableMult {
                    a: "G".into(),
                    b: "G".into(),
                    dest: MultDest::Table { out: "C".into() },
                    exec: ExecHint::Stream,
                })
                .expect("tablemult failed");
            if let Response::MultStats(s) = r {
                println!(
                    "server TableMult: {} rows contracted, {} partial products, peak {} row entries",
                    s.rows_contracted, s.partial_products, s.peak_row_entries
                );
            }
        }
        "client" => {
            let c = server
                .handle(Request::TableMult {
                    a: "G".into(),
                    b: "G".into(),
                    dest: MultDest::Client,
                    exec: ExecHint::Memory { limit: usize::MAX },
                })
                .expect("tablemult failed")
                .into_assoc()
                .expect("assoc response");
            println!("client TableMult: {} output nnz", c.nnz());
        }
        "dense" => {
            if !server.has_engine() {
                eprintln!("no dense engine attached to this coordinator");
                std::process::exit(2);
            }
            let c = server
                .handle(Request::TableMult {
                    a: "G".into(),
                    b: "G".into(),
                    dest: MultDest::Client,
                    exec: ExecHint::Dense { tile: 128 },
                })
                .expect("tablemult failed")
                .into_assoc()
                .expect("assoc response");
            println!(
                "dense TableMult via blocked GEMM: {} output nnz, {} kernel calls",
                c.nnz(),
                server.engine().map(|e| e.calls.get()).unwrap_or(0)
            );
        }
        other => {
            eprintln!("unknown mode {other}; use server|client|dense");
            std::process::exit(2);
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!("mode={mode} edges={edges} elapsed={dt:.3}s rate={}", fmt_rate(edges as f64 / dt));
}

fn cmd_bfs(flags: HashMap<String, String>) {
    let scale: u32 = flag(&flags, "scale", 12);
    let hops: usize = flag(&flags, "hops", 3);
    let server = D4mServer::new();
    ingest_kronecker(&server, scale, 4, 4096);
    let seed = d4m::gen::vertex_key(1);
    let t0 = std::time::Instant::now();
    if let Response::Distances(d) = server
        .handle(Request::Bfs { table: "G".into(), seeds: vec![seed.clone()], hops })
        .expect("bfs failed")
    {
        println!(
            "bfs from {seed}: reached {} vertices in {} hops ({:.3}s)",
            d.len(),
            hops,
            t0.elapsed().as_secs_f64()
        );
    }
}

fn cmd_jaccard(flags: HashMap<String, String>) {
    let scale: u32 = flag(&flags, "scale", 8);
    let server = D4mServer::new();
    ingest_kronecker(&server, scale, 4, 4096);
    let t0 = std::time::Instant::now();
    let j = server
        .handle(Request::Jaccard { table: "G".into(), out: "J".into() })
        .expect("jaccard failed")
        .into_assoc()
        .expect("assoc response");
    println!("jaccard: {} coefficient pairs ({:.3}s)", j.nnz(), t0.elapsed().as_secs_f64());
}

fn cmd_ktruss(flags: HashMap<String, String>) {
    let scale: u32 = flag(&flags, "scale", 8);
    let k: usize = flag(&flags, "k", 3);
    let server = D4mServer::new();
    ingest_kronecker(&server, scale, 4, 4096);
    let t0 = std::time::Instant::now();
    let kt = server
        .handle(Request::KTruss { table: "G".into(), k })
        .expect("ktruss failed")
        .into_assoc()
        .expect("assoc response");
    println!("{k}-truss: {} surviving edges ({:.3}s)", kt.nnz(), t0.elapsed().as_secs_f64());
}

fn cmd_pagerank(flags: HashMap<String, String>) {
    let scale: u32 = flag(&flags, "scale", 10);
    let server = D4mServer::new();
    ingest_kronecker(&server, scale, 4, 4096);
    let t0 = std::time::Instant::now();
    if let Response::Ranks(r) = server
        .handle(Request::PageRank {
            table: "G".into(),
            opts: d4m::graphulo::PageRankOpts::default(),
        })
        .expect("pagerank failed")
    {
        let mut top: Vec<_> = r.scores.iter().collect();
        top.sort_by(|a, b| b.1.partial_cmp(a.1).unwrap());
        println!(
            "pagerank: {} vertices, {} iters, converged={} ({:.3}s)",
            r.scores.len(),
            r.iterations,
            r.converged,
            t0.elapsed().as_secs_f64()
        );
        for (v, s) in top.into_iter().take(5) {
            println!("  {v}: {s:.5}");
        }
    }
}

/// Resolve `--kernel-threads`: absent = hardware default; `0`, junk, or
/// absurd values are rejected by the typed validator and clamped to the
/// hardware default with a warning.
fn resolve_kernel_threads(raw: Option<&str>) -> usize {
    use d4m::assoc::kernel;
    let Some(raw) = raw else {
        return kernel::default_threads();
    };
    let requested = raw.parse::<usize>().unwrap_or(0);
    match kernel::validated_threads(requested) {
        Ok(n) => n,
        Err(e) => {
            let fallback = kernel::default_threads();
            eprintln!("d4m serve: {e}; clamping --kernel-threads to {fallback}");
            fallback
        }
    }
}

fn cmd_serve(flags: HashMap<String, String>) {
    let addr: String = flag(&flags, "addr", "127.0.0.1:4950".to_string());
    let max_conns: usize = flag(&flags, "max-conns", 64);
    let workers: usize = flag(&flags, "workers", NetOpts::default().workers_per_conn);
    let kernel_threads = resolve_kernel_threads(flags.get("kernel-threads").map(String::as_str));
    d4m::assoc::kernel::configure(
        d4m::assoc::kernel::KernelConfig::detect().with_threads(kernel_threads),
    );
    println!("d4m serve: kernel pool: {kernel_threads} threads");
    let data_dir = flags.get("data-dir").cloned().filter(|d| !d.is_empty());
    let server = match &data_dir {
        Some(dir) => {
            let flush_bytes: usize =
                flag(&flags, "flush-bytes", TabletConfig::default().memtable_flush_bytes);
            let store = match KvStore::open(
                dir,
                TabletConfig { memtable_flush_bytes: flush_bytes, ..Default::default() },
                StorageConfig::default(),
            ) {
                Ok(s) => Arc::new(s),
                Err(e) => {
                    eprintln!("d4m serve: open data dir {dir} failed: {e}");
                    std::process::exit(1);
                }
            };
            let recovered = store.list_tables();
            if !recovered.is_empty() {
                println!(
                    "d4m serve: recovered {} tables from {dir}: {}",
                    recovered.len(),
                    recovered.join(", ")
                );
            }
            match D4mServer::with_store(store) {
                Ok(s) => Arc::new(s),
                Err(e) => {
                    eprintln!("d4m serve: rebinding recovered tables failed: {e}");
                    std::process::exit(1);
                }
            }
        }
        None => Arc::new(D4mServer::new()),
    };
    let opts = NetOpts { max_conns, workers_per_conn: workers, ..Default::default() };
    let mut handle = match d4m::net::serve(server, &addr, opts) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("d4m serve: bind {addr} failed: {e}");
            std::process::exit(1);
        }
    };
    println!("d4m serve: listening on {} (max {} connections)", handle.addr(), max_conns);
    handle.wait(); // until a client sends the shutdown frame
    println!("d4m serve: shut down cleanly");
    for s in handle.snapshots() {
        println!("{s}");
    }
}

/// `d4m client <sub> [--addr H:P] ...` — drive a remote coordinator.
fn cmd_client(args: &[String]) {
    let sub = args.first().map(String::as_str).unwrap_or("help");
    let flags = parse_flags(args.get(1..).unwrap_or(&[]));
    let addr: String = flag(&flags, "addr", "127.0.0.1:4950".to_string());
    let retries: u32 = flag(&flags, "retries", 25);
    let connect = || -> RemoteD4m {
        let probe = RetryPolicy::probe(retries, Duration::from_millis(200));
        match RemoteD4m::connect_with(&addr, probe) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("d4m client: connect {addr} failed: {e}");
                std::process::exit(1);
            }
        }
    };
    let check = |what: &str, r: d4m::Result<()>| {
        if let Err(e) = r {
            eprintln!("d4m client: {what} failed: {e}");
            std::process::exit(1);
        }
    };
    match sub {
        "ping" => {
            let c = connect();
            check("ping", c.ping());
            println!("pong from {addr}");
        }
        "tables" => {
            let c = connect();
            match c.list_tables() {
                Ok(ts) => {
                    for t in ts {
                        println!("{t}");
                    }
                }
                Err(e) => check("tables", Err(e)),
            }
        }
        "quickstart" => client_quickstart(&connect()),
        "query" => {
            // positional table first (`d4m client query G --rows ...`),
            // falling back to --table
            let table = args
                .get(1)
                .filter(|s| !s.starts_with("--"))
                .cloned()
                .unwrap_or_else(|| flag(&flags, "table", "G".to_string()));
            let mut q = TableQuery::all()
                .rows(parse_keysel(&flag(&flags, "rows", String::new())))
                .cols(parse_keysel(&flag(&flags, "cols", String::new())));
            let limit: usize = flag(&flags, "limit", 0);
            if limit > 0 {
                q = q.limit(limit);
            }
            client_query(&connect(), &table, q);
        }
        "plan" => {
            let src = args.get(1).filter(|s| !s.starts_with("--")).cloned().unwrap_or_default();
            if src.is_empty() {
                eprintln!("usage: d4m client plan '<expr>' [--addr H:P]");
                std::process::exit(2);
            }
            client_plan(&connect(), &src);
        }
        "scan4" => {
            let clients: usize = flag(&flags, "clients", 4);
            let passes: usize = flag(&flags, "passes", 8);
            client_scan_concurrent(&addr, retries, clients, passes);
        }
        "scan-pages" => {
            let table: String = flag(&flags, "table", "G".to_string());
            let page: usize = flag(&flags, "page", 2);
            let query = TableQuery::all()
                .rows(parse_keysel(&flag(&flags, "rows", String::new())))
                .cols(parse_keysel(&flag(&flags, "cols", String::new())));
            client_scan_pages(&connect(), &table, query, page);
        }
        "pipeline-bench" => {
            let table: String = flag(&flags, "table", "G".to_string());
            let inflight: usize = flag(&flags, "inflight", 8);
            let requests: usize = flag(&flags, "requests", 200);
            client_pipeline_bench(&connect(), &table, inflight, requests);
        }
        "ingest-batches" => {
            let table: String = flag(&flags, "table", "K".to_string());
            let batches: usize = flag(&flags, "batches", 100);
            let batch_size: usize = flag(&flags, "batch-size", 100);
            client_ingest_batches(&connect(), &table, batches, batch_size);
        }
        "verify-batches" => {
            let table: String = flag(&flags, "table", "K".to_string());
            let upto: usize = flag(&flags, "upto", 0);
            let batch_size: usize = flag(&flags, "batch-size", 100);
            client_verify_batches(&connect(), &table, upto, batch_size);
        }
        "stats" => {
            let c = connect();
            match c.stats() {
                Ok(snaps) => {
                    for s in snaps {
                        println!("{s}");
                    }
                    // this client's own healing counters ride along so
                    // a chaos run can read its retries from the output
                    for s in c.client_snapshots() {
                        println!("{s}");
                    }
                }
                Err(e) => check("stats", Err(e)),
            }
        }
        "shutdown" => {
            let c = connect();
            check("shutdown", c.shutdown_server());
            println!("server at {addr} acknowledged shutdown");
        }
        other => {
            eprintln!(
                "usage: d4m client <ping|tables|quickstart|query|plan|scan4|\
                 scan-pages|pipeline-bench|ingest-batches|verify-batches|\
                 stats|shutdown> \
                 [--addr H:P] [--retries N] [--clients N] [--passes N] \
                 [--table T] [--rows SEL] [--cols SEL] [--limit N] \
                 [--page N] [--inflight N] [--requests N] \
                 [--batches N] [--batch-size N] [--upto N] (got {other:?})"
            );
            std::process::exit(2);
        }
    }
}

/// Deterministic batched ingest for the crash-recovery e2e: batch `j`
/// writes rows `r{j:05}x{k:04}` (value "1") and `acked <j>` is printed
/// only after the server's reply arrives, so every printed line is a
/// durability promise `verify-batches` can hold the store to after a
/// kill -9 (Rust's stdout is line-buffered even into a pipe — an acked
/// line is out before the next request is issued).
fn client_ingest_batches(c: &RemoteD4m, table: &str, batches: usize, batch_size: usize) {
    ok_or_die("create_table", c.create_table(table, vec![]));
    let pipeline = PipelineConfig { num_workers: 2, ..Default::default() };
    for j in 0..batches {
        let triples: Vec<(String, String, String)> = (0..batch_size)
            .map(|k| (format!("r{j:05}x{k:04}"), "c".to_string(), "1".to_string()))
            .collect();
        ok_or_die("ingest", c.ingest(table, triples, pipeline.clone()));
        println!("acked {j}");
    }
}

/// Check a (recovered) table against the `acked` count printed by
/// `ingest-batches`: every row of every acked batch must read back with
/// value exactly 1 — absence means an acknowledged write was lost.
/// Extra rows are tolerated only if they belong to batches at or after
/// `upto` (the in-flight batch the kill interrupted — replay may
/// legitimately restore a prefix of it); anything else, or a mangled
/// value anywhere, exits nonzero. (Exact-once replay at the physical
/// layer is asserted by the `storage_recovery` integration tests — a
/// replayed duplicate carries its original timestamp, so the versioning
/// scan here would dedup it.)
fn client_verify_batches(c: &RemoteD4m, table: &str, upto: usize, batch_size: usize) {
    let a = ok_or_die("query", c.query(table, TableQuery::all()));
    for j in 0..upto {
        for k in 0..batch_size {
            let row = format!("r{j:05}x{k:04}");
            let v = a.get(&row, "c");
            assert_or_die(v == 1.0, &format!("acked row {row}: expected 1, got {v}"));
        }
    }
    let expected = upto * batch_size;
    let mut extras = 0usize;
    for (row, _col, v) in a.triples() {
        let batch: usize = row.get(1..6).and_then(|s| s.parse().ok()).unwrap_or(usize::MAX);
        if batch >= upto {
            extras += 1;
            assert_or_die(v == 1.0, &format!("in-flight row {row}: expected 1, got {v}"));
        }
    }
    assert_or_die(
        a.nnz() == expected + extras,
        &format!("nnz {} != {expected} acked + {extras} in-flight", a.nnz()),
    );
    println!(
        "verify-batches: table {table}: {expected} acked entries present exactly once \
         (+{extras} from the interrupted batch)"
    );
}

/// `d4m client query T --rows SEL --cols SEL --limit N` — a selective
/// remote read with the selectors pushed down server-side (the shared
/// [`parse_keysel`] grammar: "a,b,", "lo,:,hi,", "pre*", ":").
fn client_query(c: &RemoteD4m, table: &str, query: TableQuery) {
    let t0 = std::time::Instant::now();
    let a = ok_or_die("query", c.query(table, query));
    for (r, col, v) in a.str_triples() {
        println!("{r}\t{col}\t{v}");
    }
    println!(
        "query: table {table}: {} entries ({:.3}s)",
        a.nnz(),
        t0.elapsed().as_secs_f64()
    );
}

/// `d4m client plan '<expr>'` — parse + compile the expression
/// client-side, execute the whole program server-side in **one** round
/// trip, print the result and the executor's fusion counters.
fn client_plan(c: &RemoteD4m, src: &str) {
    let t0 = std::time::Instant::now();
    let (a, stats) = ok_or_die("plan", c.plan_expr(src));
    for (r, col, v) in a.str_triples() {
        println!("{r}\t{col}\t{v}");
    }
    println!(
        "plan: {} entries in one round trip ({:.3}s); {stats}",
        a.nnz(),
        t0.elapsed().as_secs_f64()
    );
}

/// Remote paged scan through a server-side cursor, checked against the
/// one-shot query: every page must respect the `page_entries` bound and
/// the assembled result must be bit-identical (the CI paged-scan leg —
/// any divergence exits nonzero).
fn client_scan_pages(c: &RemoteD4m, table: &str, query: TableQuery, page: usize) {
    let t0 = std::time::Instant::now();
    let reference = ok_or_die("one-shot query", c.query(table, query.clone()));
    let mut pages = 0usize;
    let mut triples: Vec<(String, String, String)> = Vec::new();
    for p in c.scan_pages(table, query, page) {
        let p = ok_or_die("cursor page", p);
        assert_or_die(p.len() <= page, "a page exceeded the page_entries bound");
        pages += 1;
        triples.extend(p);
    }
    let total = triples.len();
    let paged = ok_or_die("assemble pages", d4m::assoc::io::parse_triples(triples));
    assert_or_die(paged == reference, "paged scan diverged from one-shot query");
    assert_or_die(
        paged.matrix() == reference.matrix(),
        "paged scan CSR diverged from one-shot query",
    );
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "scan-pages: table {table}: {total} entries in {pages} pages of <= {page} \
         ({:.3}s, {}), bit-identical to one-shot query",
        dt,
        fmt_rate(total as f64 / dt)
    );
    println!(
        "scan-pages healing: {} retries, {} reconnects, {} cursor resumes",
        c.retry_count(),
        c.reconnect_count(),
        c.cursor_resume_count()
    );
}

/// Pipelined round-trips on ONE connection: keep `inflight` requests in
/// flight, and claim responses newest-first so correlation is exercised
/// against genuinely out-of-order completion. Requests alternate two
/// shapes (ListTables / Query) and every response must match its
/// request's shape — a misrouted id exits nonzero (the CI pipelining
/// leg).
fn client_pipeline_bench(c: &RemoteD4m, table: &str, inflight: usize, requests: usize) {
    let inflight = inflight.max(1);
    let requests = requests.max(1);
    // warm reference so response shapes are predictable
    let reference = ok_or_die("reference query", c.query(table, TableQuery::all()));
    let t0 = std::time::Instant::now();
    let mut window: VecDeque<(u64, bool)> = VecDeque::with_capacity(inflight);
    let mut issued = 0usize;
    let mut completed = 0usize;
    let mut out_of_submission_order = 0usize;
    let mut last_claimed_id = 0u64;
    while completed < requests {
        while window.len() < inflight && issued < requests {
            let expect_tables = issued % 2 == 0;
            let req = if expect_tables {
                Request::ListTables
            } else {
                Request::Query { table: table.into(), query: TableQuery::all() }
            };
            let id = ok_or_die("submit", c.submit(req));
            window.push_back((id, expect_tables));
            issued += 1;
        }
        // LIFO claim: the newest-submitted id is waited on first, so
        // earlier ids' frames arrive while we wait and must be parked
        // and re-correlated
        let (id, expect_tables) = window.pop_back().expect("window non-empty");
        if id < last_claimed_id {
            out_of_submission_order += 1;
        }
        last_claimed_id = id;
        match ok_or_die("wait", c.wait(id)) {
            Response::Tables(ts) => {
                assert_or_die(expect_tables, "Tables response correlated to a Query id");
                assert_or_die(
                    ts.iter().any(|t| t.as_str() == table),
                    "pipelined ListTables lost the table",
                );
            }
            Response::Assoc(a) => {
                assert_or_die(!expect_tables, "Assoc response correlated to a ListTables id");
                assert_or_die(a == reference, "pipelined query answer diverged");
            }
            other => {
                eprintln!("pipeline-bench: unexpected response variant {other:?}");
                std::process::exit(1);
            }
        }
        completed += 1;
    }
    assert_or_die(
        out_of_submission_order > 0 || requests <= inflight,
        "no out-of-submission-order claims — pipelining not exercised",
    );
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "pipeline-bench: {requests} requests, {inflight} in flight on one connection, \
         {:.3}s ({}), {} claimed out of submission order, correlation OK",
        dt,
        fmt_rate(requests as f64 / dt),
        out_of_submission_order
    );
}

/// The remote quickstart: the associative-array tour driven end-to-end
/// over the wire, with the CI assertions inline — any divergence from
/// the in-process semantics exits nonzero.
fn client_quickstart(c: &RemoteD4m) {
    println!("== D4M remote quickstart ==");
    ok_or_die("create_table", c.create_table("G", vec![]));
    let triples: Vec<(String, String, String)> = vec![
        ("a".into(), "b".into(), "1".into()),
        ("b".into(), "c".into(), "1".into()),
        ("a".into(), "c".into(), "1".into()),
        ("c".into(), "d".into(), "1".into()),
    ];
    let pipeline = PipelineConfig { num_workers: 2, ..Default::default() };
    let rep = ok_or_die("ingest", c.ingest("G", triples, pipeline));
    println!("ingest: {rep}");
    let a = ok_or_die("query", c.query("G", TableQuery::all()));
    println!("G =\n{}", display_full(&a));
    assert_or_die(a.nnz() == 4, "full query should see 4 edges");
    let by_col = TableQuery::all().cols(KeySel::keys(&["c"]));
    let col = ok_or_die("column query", c.query("G", by_col));
    assert_or_die(col.nnz() == 2, "column query for 'c' should see 2 edges");
    let d = ok_or_die("bfs", c.bfs("G", &["a"], 2));
    println!("bfs from a: {} vertices reached", d.len());
    assert_or_die(d.get("d") == Some(&2), "bfs should reach d at hop 2");
    let m = ok_or_die("tablemult", c.tablemult_client("G", "G", usize::MAX));
    println!("G'*G has {} entries", m.nnz());
    assert_or_die(!m.is_empty(), "tablemult product should be non-empty");
    println!("remote quickstart: OK");
}

fn ok_or_die<T>(what: &str, r: d4m::Result<T>) -> T {
    r.unwrap_or_else(|e| {
        eprintln!("remote quickstart: {what} failed: {e}");
        std::process::exit(1);
    })
}

fn assert_or_die(cond: bool, what: &str) {
    if !cond {
        eprintln!("remote quickstart: FAILED: {what}");
        std::process::exit(1);
    }
}

/// N concurrent remote clients, each on its own connection, each issuing
/// the same full-table query `passes` times; all answers must agree
/// exactly (the concurrent-remote-reader leg of the CI e2e).
fn client_scan_concurrent(addr: &str, retries: u32, clients: usize, passes: usize) {
    let t0 = std::time::Instant::now();
    let mut results: Vec<(usize, Vec<d4m::assoc::Triple>)> = Vec::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients.max(1))
            .map(|i| {
                s.spawn(move || {
                    let probe = RetryPolicy::probe(retries, Duration::from_millis(200));
                    let c = RemoteD4m::connect_with(addr, probe).unwrap_or_else(|e| {
                        eprintln!("scan4 client {i}: connect failed: {e}");
                        std::process::exit(1);
                    });
                    let mut entries = 0usize;
                    let mut last: Vec<d4m::assoc::Triple> = Vec::new();
                    for _ in 0..passes.max(1) {
                        let a = c.query("G", TableQuery::all()).unwrap_or_else(|e| {
                            eprintln!("scan4 client {i}: query failed: {e}");
                            std::process::exit(1);
                        });
                        entries += a.nnz();
                        last = a.triples();
                    }
                    (entries, last)
                })
            })
            .collect();
        for h in handles {
            results.push(h.join().expect("scan client panicked"));
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    let first = &results[0].1;
    for (i, (_, triples)) in results.iter().enumerate() {
        if triples != first {
            eprintln!("scan4: client {i} saw a different answer than client 0");
            std::process::exit(2);
        }
    }
    let total: usize = results.iter().map(|(n, _)| n).sum();
    println!(
        "scan4: {} clients x {} passes, {} entries in {:.3}s ({}), answers identical",
        clients,
        passes,
        total,
        dt,
        fmt_rate(total as f64 / dt)
    );
}

/// `d4m chaos` — run the fault-injection proxy in front of a serving
/// coordinator until the process is killed (the CI chaos leg runs the
/// whole client workload through it, then kills it).
fn cmd_chaos(flags: HashMap<String, String>) {
    let listen: String = flag(&flags, "listen", "127.0.0.1:4960".to_string());
    let upstream: String = flag(&flags, "upstream", "127.0.0.1:4950".to_string());
    let seed: u64 = flag(&flags, "seed", 0xC4A0_5EED);
    let name: String = flag(&flags, "profile", "none".to_string());
    let rate: f64 = flag(&flags, "rate", 0.01);
    let delay_ms: u64 = flag(&flags, "delay-ms", 20);
    let profile = match Profile::parse(&name, rate, delay_ms) {
        Some(p) => p,
        None => {
            eprintln!("d4m chaos: unknown profile {name}; use drop|delay|corrupt|mixed|none");
            std::process::exit(2);
        }
    };
    let opts = ChaosOpts { seed, profile, scripted: Vec::new() };
    let proxy = match ChaosProxy::start(&listen, &upstream, opts) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("d4m chaos: bind {listen} failed: {e}");
            std::process::exit(1);
        }
    };
    println!(
        "d4m chaos: {} -> {upstream}, profile {name} rate {rate} seed {seed:#x}",
        proxy.addr()
    );
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

fn cmd_tables() {
    let server = D4mServer::new();
    ingest_kronecker(&server, 8, 2, 1024);
    if let Ok(Response::Tables(ts)) = server.handle(Request::ListTables) {
        for t in ts {
            println!("{t}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[cfg_attr(miri, ignore)]
    fn kernel_threads_flag_clamps_invalid_values() {
        let hw = d4m::assoc::kernel::default_threads();
        assert_eq!(resolve_kernel_threads(None), hw);
        assert_eq!(resolve_kernel_threads(Some("8")), 8);
        assert_eq!(resolve_kernel_threads(Some("1")), 1);
        // zero, junk, and absurd values all clamp to the hardware default
        assert_eq!(resolve_kernel_threads(Some("0")), hw);
        assert_eq!(resolve_kernel_threads(Some("not-a-number")), hw);
        assert_eq!(resolve_kernel_threads(Some("100000")), hw);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn parse_flags_keeps_kernel_threads_value() {
        let args: Vec<String> =
            ["--kernel-threads", "4", "--addr", "h:1"].iter().map(|s| s.to_string()).collect();
        let f = parse_flags(&args);
        assert_eq!(f.get("kernel-threads").map(String::as_str), Some("4"));
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = parse_flags(&args[args.len().min(1)..]);
    match cmd {
        "demo" => cmd_demo(),
        "ingest" => cmd_ingest(flags),
        "tablemult" => cmd_tablemult(flags),
        "bfs" => cmd_bfs(flags),
        "jaccard" => cmd_jaccard(flags),
        "ktruss" => cmd_ktruss(flags),
        "pagerank" => cmd_pagerank(flags),
        "tables" => cmd_tables(),
        "serve" => cmd_serve(flags),
        "client" => cmd_client(&args[1..]),
        "chaos" => cmd_chaos(flags),
        _ => {
            eprintln!(
                "usage: d4m <demo|ingest|tablemult|bfs|jaccard|ktruss|pagerank|tables|serve|client|chaos> [--flag value ...]"
            );
            std::process::exit(2);
        }
    }
}
