//! D4M CLI — the leader entrypoint: drives the coordinator over the
//! embedded engines. Hand-rolled argument parsing (no clap in the
//! offline vendor set).
//!
//! ```text
//! d4m demo                          quickstart associative-array tour
//! d4m ingest  [--scale S] [--workers W] [--batch B]   pipeline ingest bench
//! d4m tablemult [--scale S] [--mode server|client|dense]
//! d4m bfs     [--scale S] [--hops H]
//! d4m jaccard [--scale S]
//! d4m ktruss  [--scale S] [--k K]
//! d4m tables                        list tables after a demo ingest
//! ```

use std::collections::HashMap;

use d4m::assoc::{io::display_full, Assoc};
use d4m::coordinator::{D4mServer, Request, Response};
use d4m::gen::{kronecker_triples, KroneckerParams};
use d4m::pipeline::PipelineConfig;
use d4m::util::fmt_rate;

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            let val = args.get(i + 1).cloned().unwrap_or_default();
            out.insert(name.to_string(), val);
            i += 2;
        } else {
            i += 1;
        }
    }
    out
}

fn flag<T: std::str::FromStr>(flags: &HashMap<String, String>, name: &str, default: T) -> T {
    flags.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn ingest_kronecker(server: &D4mServer, scale: u32, workers: usize, batch: usize) -> u64 {
    let triples = kronecker_triples(&KroneckerParams::new(scale, 16, 20170710));
    let n = triples.len() as u64;
    let rep = server
        .handle(Request::Ingest {
            table: "G".into(),
            triples,
            pipeline: PipelineConfig {
                num_workers: workers,
                batch_size: batch,
                ..Default::default()
            },
        })
        .expect("ingest failed");
    if let Response::Ingested(r) = rep {
        println!("ingest: {r}");
    }
    n
}

fn cmd_demo() {
    println!("== D4M 3.0 quickstart ==");
    let a = Assoc::from_triples(&[
        ("alice", "carol", 1.0),
        ("alice", "bob", 1.0),
        ("bob", "carol", 2.0),
    ]);
    println!("A =\n{}", display_full(&a));
    println!("A' =\n{}", display_full(&a.transpose()));
    println!("A' * A =\n{}", display_full(&a.transpose().matmul(&a)));
    let deg = a.sum(1);
    println!("column degrees =\n{}", display_full(&deg));
}

fn cmd_ingest(flags: HashMap<String, String>) {
    let scale: u32 = flag(&flags, "scale", 12);
    let workers: usize = flag(&flags, "workers", 4);
    let batch: usize = flag(&flags, "batch", 2048);
    let server = D4mServer::new();
    println!("kronecker SCALE={scale} ef=16, {workers} workers, batch {batch}");
    ingest_kronecker(&server, scale, workers, batch);
    for s in server.snapshots() {
        println!("{s}");
    }
}

fn cmd_tablemult(flags: HashMap<String, String>) {
    let scale: u32 = flag(&flags, "scale", 10);
    let mode: String = flag(&flags, "mode", "server".to_string());
    let server = D4mServer::new();
    let edges = ingest_kronecker(&server, scale, 4, 4096);
    let t0 = std::time::Instant::now();
    match mode.as_str() {
        "server" => {
            let r = server
                .handle(Request::TableMult { a: "G".into(), b: "G".into(), out: "C".into() })
                .expect("tablemult failed");
            if let Response::MultStats(s) = r {
                println!(
                    "server TableMult: {} rows contracted, {} partial products, peak {} row entries",
                    s.rows_contracted, s.partial_products, s.peak_row_entries
                );
            }
        }
        "client" => {
            let c = server
                .handle(Request::TableMultClient {
                    a: "G".into(),
                    b: "G".into(),
                    memory_limit: usize::MAX,
                })
                .expect("tablemult failed")
                .into_assoc()
                .expect("assoc response");
            println!("client TableMult: {} output nnz", c.nnz());
        }
        "dense" => {
            if !server.has_engine() {
                eprintln!("no PJRT artifacts found — run `make artifacts` first");
                std::process::exit(2);
            }
            let c = server
                .handle(Request::TableMultDense { a: "G".into(), b: "G".into(), tile: 128 })
                .expect("tablemult failed")
                .into_assoc()
                .expect("assoc response");
            println!(
                "dense TableMult via PJRT: {} output nnz, {} kernel calls",
                c.nnz(),
                server.engine().map(|e| e.calls.get()).unwrap_or(0)
            );
        }
        other => {
            eprintln!("unknown mode {other}; use server|client|dense");
            std::process::exit(2);
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!("mode={mode} edges={edges} elapsed={dt:.3}s rate={}", fmt_rate(edges as f64 / dt));
}

fn cmd_bfs(flags: HashMap<String, String>) {
    let scale: u32 = flag(&flags, "scale", 12);
    let hops: usize = flag(&flags, "hops", 3);
    let server = D4mServer::new();
    ingest_kronecker(&server, scale, 4, 4096);
    let seed = d4m::gen::vertex_key(1);
    let t0 = std::time::Instant::now();
    if let Response::Distances(d) = server
        .handle(Request::Bfs { table: "G".into(), seeds: vec![seed.clone()], hops })
        .expect("bfs failed")
    {
        println!(
            "bfs from {seed}: reached {} vertices in {} hops ({:.3}s)",
            d.len(),
            hops,
            t0.elapsed().as_secs_f64()
        );
    }
}

fn cmd_jaccard(flags: HashMap<String, String>) {
    let scale: u32 = flag(&flags, "scale", 8);
    let server = D4mServer::new();
    ingest_kronecker(&server, scale, 4, 4096);
    let t0 = std::time::Instant::now();
    let j = server
        .handle(Request::Jaccard { table: "G".into(), out: "J".into() })
        .expect("jaccard failed")
        .into_assoc()
        .expect("assoc response");
    println!("jaccard: {} coefficient pairs ({:.3}s)", j.nnz(), t0.elapsed().as_secs_f64());
}

fn cmd_ktruss(flags: HashMap<String, String>) {
    let scale: u32 = flag(&flags, "scale", 8);
    let k: usize = flag(&flags, "k", 3);
    let server = D4mServer::new();
    ingest_kronecker(&server, scale, 4, 4096);
    let t0 = std::time::Instant::now();
    let kt = server
        .handle(Request::KTruss { table: "G".into(), k })
        .expect("ktruss failed")
        .into_assoc()
        .expect("assoc response");
    println!("{k}-truss: {} surviving edges ({:.3}s)", kt.nnz(), t0.elapsed().as_secs_f64());
}

fn cmd_pagerank(flags: HashMap<String, String>) {
    let scale: u32 = flag(&flags, "scale", 10);
    let server = D4mServer::new();
    ingest_kronecker(&server, scale, 4, 4096);
    let t0 = std::time::Instant::now();
    if let Response::Ranks(r) = server
        .handle(Request::PageRank {
            table: "G".into(),
            opts: d4m::graphulo::PageRankOpts::default(),
        })
        .expect("pagerank failed")
    {
        let mut top: Vec<_> = r.scores.iter().collect();
        top.sort_by(|a, b| b.1.partial_cmp(a.1).unwrap());
        println!(
            "pagerank: {} vertices, {} iters, converged={} ({:.3}s)",
            r.scores.len(),
            r.iterations,
            r.converged,
            t0.elapsed().as_secs_f64()
        );
        for (v, s) in top.into_iter().take(5) {
            println!("  {v}: {s:.5}");
        }
    }
}

fn cmd_tables() {
    let server = D4mServer::new();
    ingest_kronecker(&server, 8, 2, 1024);
    if let Ok(Response::Tables(ts)) = server.handle(Request::ListTables) {
        for t in ts {
            println!("{t}");
        }
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = parse_flags(&args[args.len().min(1)..]);
    match cmd {
        "demo" => cmd_demo(),
        "ingest" => cmd_ingest(flags),
        "tablemult" => cmd_tablemult(flags),
        "bfs" => cmd_bfs(flags),
        "jaccard" => cmd_jaccard(flags),
        "ktruss" => cmd_ktruss(flags),
        "pagerank" => cmd_pagerank(flags),
        "tables" => cmd_tables(),
        _ => {
            eprintln!(
                "usage: d4m <demo|ingest|tablemult|bfs|jaccard|ktruss|pagerank|tables> [--flag value ...]"
            );
            std::process::exit(2);
        }
    }
}
