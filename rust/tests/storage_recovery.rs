//! Crash-recovery and hostile-input protocol tests for the durable
//! storage engine (DESIGN.md §Durable storage).
//!
//! The crash model: dropping a [`KvStore`] without a checkpoint is the
//! kill -9 — acknowledged writes exist only in the WAL (the per-append
//! `BufWriter` flush puts them in the OS before any ack) and the
//! memtables they were routed to die with the process. Recovery must
//! reproduce a **bit-identical** scan for the surviving WAL prefix, and
//! no on-disk corruption — torn tails, bit flips, garbage suffixes,
//! orphan files — may ever panic the open path: it recovers a prefix or
//! fails with a typed [`D4mError::Storage`].

// Integration-test scaffolding: unwrap/expect on setup is idiomatic
// here; clippy.toml's disallowed-methods targets library code.
#![allow(clippy::disallowed_methods)]
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use d4m::kvstore::{Entry, IterConfig, KvStore, RowRange, StorageConfig, TabletConfig};
use d4m::D4mError;

fn tmp_dir(tag: &str) -> PathBuf {
    static N: AtomicU64 = AtomicU64::new(0);
    let d = std::env::temp_dir().join(format!(
        "d4m-recovery-{tag}-{}-{}",
        std::process::id(),
        N.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn open(dir: &Path) -> KvStore {
    KvStore::open(dir, TabletConfig::default(), StorageConfig::default()).unwrap()
}

fn scan_all(t: &d4m::kvstore::Table) -> Vec<Entry> {
    t.scan(&RowRange::all(), &IterConfig::default())
}

/// Recursive copy (the scratch-corruption tests mutate a copy, keeping
/// the pristine post-crash image intact for the next variant).
fn copy_dir(src: &Path, dst: &Path) {
    std::fs::create_dir_all(dst).unwrap();
    for entry in std::fs::read_dir(src).unwrap() {
        let entry = entry.unwrap();
        let to = dst.join(entry.file_name());
        if entry.file_type().unwrap().is_dir() {
            copy_dir(&entry.path(), &to);
        } else {
            std::fs::copy(entry.path(), &to).unwrap();
        }
    }
}

/// The single `wal-*.log` of a single-tablet table directory.
fn the_wal(table_dir: &Path) -> PathBuf {
    let mut wals: Vec<PathBuf> = std::fs::read_dir(table_dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .map(|n| n.starts_with("wal-") && n.ends_with(".log"))
                .unwrap_or(false)
        })
        .collect();
    wals.sort();
    assert_eq!(wals.len(), 1, "expected exactly one WAL in {}", table_dir.display());
    wals.pop().unwrap()
}

#[test]
fn unflushed_writes_survive_reopen_bit_identical() {
    let dir = tmp_dir("unflushed");
    let before;
    {
        let store = open(&dir);
        let t = store.create_table("t", vec!["m".into()]).unwrap();
        for i in 0..200 {
            t.put(&format!("r{i:04}"), "c", &i.to_string()).unwrap();
        }
        t.delete("r0000", "c").unwrap();
        t.put("r0001", "c", "rewritten").unwrap();
        before = scan_all(&t);
        // dropped WITHOUT checkpoint: everything lives only in the WAL
    }
    let store = open(&dir);
    let t = store.table("t").unwrap();
    assert_eq!(t.num_tablets(), 2, "splits must recover from the manifest");
    assert_eq!(scan_all(&t), before, "recovered scan must be bit-identical");
    // the recovered clock is past every replayed timestamp: a new write
    // must supersede, not be shadowed by, its recovered predecessor
    t.put("r0001", "c", "post-recovery").unwrap();
    let now = scan_all(&t);
    let e = now.iter().find(|e| e.key.row == "r0001").unwrap();
    assert_eq!(e.value, "post-recovery");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn checkpointed_runs_plus_wal_tail_recover_together() {
    let dir = tmp_dir("ckpt-tail");
    let before;
    {
        let store = open(&dir);
        let t = store.create_table("t", vec![]).unwrap();
        for i in 0..100 {
            t.put(&format!("a{i:04}"), "c", "frozen").unwrap();
        }
        store.checkpoint().unwrap();
        for i in 0..100 {
            t.put(&format!("b{i:04}"), "c", "tail").unwrap();
        }
        t.delete("a0000", "c").unwrap(); // tombstone over a frozen run
        before = scan_all(&t);
    }
    let store = open(&dir);
    let t = store.table("t").unwrap();
    assert_eq!(scan_all(&t), before);
    assert!(!scan_all(&t).iter().any(|e| e.key.row == "a0000"), "tombstone lost");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn recovery_is_idempotent_across_repeated_crashes() {
    // open → recover → crash again (no checkpoint): the second recovery
    // replays the same WALs and must not double-apply anything.
    let dir = tmp_dir("idempotent");
    let before;
    {
        let store = open(&dir);
        let t = store.create_table("t", vec![]).unwrap();
        for i in 0..50 {
            t.put(&format!("r{i:03}"), "c", "1").unwrap();
        }
        before = scan_all(&t);
    }
    for _ in 0..3 {
        let store = open(&dir);
        let t = store.table("t").unwrap();
        assert_eq!(scan_all(&t), before);
        assert_eq!(t.raw_len(), 50, "replay duplicated entries");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

/// The crash-recovery protocol test of the issue: truncate the WAL at
/// EVERY byte cut and reopen. No cut may panic or error (the magic
/// survives or the file reads as empty), and every cut must recover a
/// clean prefix of the acknowledged writes, bit-identically.
#[test]
fn torn_wal_tail_recovers_a_clean_prefix_at_every_cut() {
    let dir = tmp_dir("torn");
    {
        let store = open(&dir);
        let t = store.create_table("t", vec![]).unwrap();
        for i in 0..6 {
            // one put per WAL record, rows in key order, so "prefix of
            // acked writes" and "prefix of the sorted scan" coincide
            t.put(&format!("r{i:03}"), "c", &format!("v{i}")).unwrap();
        }
    }
    let wal = the_wal(&dir.join("t"));
    let pristine = std::fs::read(&wal).unwrap();
    let scratch = tmp_dir("torn-scratch");
    let mut recovered_at: Vec<usize> = Vec::new();
    for cut in 0..=pristine.len() {
        let _ = std::fs::remove_dir_all(&scratch);
        copy_dir(&dir, &scratch);
        std::fs::write(the_wal(&scratch.join("t")), &pristine[..cut]).unwrap();
        let store = open(&scratch); // must never panic or fail
        let rows: Vec<String> =
            scan_all(&store.table("t").unwrap()).iter().map(|e| e.key.row.clone()).collect();
        let m = rows.len();
        assert!(m <= 6);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row, &format!("r{i:03}"), "cut {cut}: not a prefix: {rows:?}");
        }
        recovered_at.push(m);
    }
    // monotone in the cut, empty at 0, complete at the full length
    assert_eq!(recovered_at[0], 0);
    assert_eq!(*recovered_at.last().unwrap(), 6);
    assert!(recovered_at.windows(2).all(|w| w[0] <= w[1]));
    std::fs::remove_dir_all(&dir).unwrap();
    let _ = std::fs::remove_dir_all(&scratch);
}

/// Flip one bit at every byte of the WAL: recovery must never panic —
/// each flip yields either a typed error (header damage) or a store
/// holding a clean prefix (the CRC catches every single-bit flip, so a
/// damaged record and everything after it vanish together).
#[test]
fn wal_bit_flips_recover_prefix_or_typed_error_never_panic() {
    let dir = tmp_dir("bitflip");
    {
        let store = open(&dir);
        let t = store.create_table("t", vec![]).unwrap();
        for i in 0..6 {
            t.put(&format!("r{i:03}"), "c", &format!("v{i}")).unwrap();
        }
    }
    let wal = the_wal(&dir.join("t"));
    let pristine = std::fs::read(&wal).unwrap();
    let scratch = tmp_dir("bitflip-scratch");
    for pos in 0..pristine.len() {
        let _ = std::fs::remove_dir_all(&scratch);
        copy_dir(&dir, &scratch);
        let mut bytes = pristine.clone();
        bytes[pos] ^= 0x01;
        std::fs::write(the_wal(&scratch.join("t")), &bytes).unwrap();
        match KvStore::open(&scratch, TabletConfig::default(), StorageConfig::default()) {
            Ok(store) => {
                let rows: Vec<String> = scan_all(&store.table("t").unwrap())
                    .iter()
                    .map(|e| e.key.row.clone())
                    .collect();
                for (i, row) in rows.iter().enumerate() {
                    assert_eq!(
                        row,
                        &format!("r{i:03}"),
                        "flip at {pos}: recovered a non-prefix: {rows:?}"
                    );
                }
            }
            Err(D4mError::Storage(_)) | Err(D4mError::Io(_)) => {} // typed refusal is fine
            Err(other) => panic!("flip at {pos}: unexpected error type {other:?}"),
        }
    }
    std::fs::remove_dir_all(&dir).unwrap();
    let _ = std::fs::remove_dir_all(&scratch);
}

#[test]
fn garbage_wal_suffix_is_ignored() {
    let dir = tmp_dir("garbage");
    let before;
    {
        let store = open(&dir);
        let t = store.create_table("t", vec![]).unwrap();
        for i in 0..20 {
            t.put(&format!("r{i:03}"), "c", "1").unwrap();
        }
        before = scan_all(&t);
    }
    let wal = the_wal(&dir.join("t"));
    let mut bytes = std::fs::read(&wal).unwrap();
    bytes.extend_from_slice(&[0xA5; 64]); // a torn half-record
    std::fs::write(&wal, &bytes).unwrap();
    let store = open(&dir);
    assert_eq!(scan_all(&store.table("t").unwrap()), before);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn corrupt_run_file_is_a_typed_error_not_a_panic() {
    let dir = tmp_dir("badrun");
    {
        let store = open(&dir);
        let t = store.create_table("t", vec![]).unwrap();
        for i in 0..50 {
            t.put(&format!("r{i:03}"), "c", "1").unwrap();
        }
        store.checkpoint().unwrap();
    }
    let run = std::fs::read_dir(dir.join("t"))
        .unwrap()
        .map(|e| e.unwrap().path())
        .find(|p| p.extension().map(|x| x == "run").unwrap_or(false))
        .expect("checkpoint must have written a run file");
    let mut bytes = std::fs::read(&run).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&run, &bytes).unwrap();
    match KvStore::open(&dir, TabletConfig::default(), StorageConfig::default()) {
        Err(D4mError::Storage(_)) => {}
        other => panic!("expected a typed Storage error, got {other:?}"),
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn orphan_run_files_are_swept_on_recovery() {
    let dir = tmp_dir("orphan");
    let before;
    {
        let store = open(&dir);
        let t = store.create_table("t", vec![]).unwrap();
        for i in 0..30 {
            t.put(&format!("r{i:03}"), "c", "1").unwrap();
        }
        store.checkpoint().unwrap();
        before = scan_all(&t);
    }
    // a flush that died after writing its run but before the manifest
    // commit leaves an unreferenced run file behind
    let tdir = dir.join("t");
    let real_run = std::fs::read_dir(&tdir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .find(|p| p.extension().map(|x| x == "run").unwrap_or(false))
        .unwrap();
    let orphan = tdir.join("run-00000000000000ff.run");
    std::fs::copy(&real_run, &orphan).unwrap();
    let store = open(&dir);
    assert!(!orphan.exists(), "orphan run must be swept");
    assert_eq!(
        scan_all(&store.table("t").unwrap()),
        before,
        "orphan sweep must not disturb live data"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn survives_reopen_after_many_flushes_and_compactions() {
    let dir = tmp_dir("compacted");
    let cfg = TabletConfig { memtable_flush_bytes: 512, max_runs: 3 };
    let before;
    {
        let store = KvStore::open(&dir, cfg.clone(), StorageConfig::default()).unwrap();
        let t = store.create_table("t", vec![]).unwrap();
        for i in 0..400 {
            // repeated rows so versioning + compaction both do real work
            t.put(&format!("r{:03}", i % 100), "c", &i.to_string()).unwrap();
        }
        before = scan_all(&t);
        assert_eq!(before.len(), 100);
    }
    let store = KvStore::open(&dir, cfg, StorageConfig::default()).unwrap();
    let t = store.table("t").unwrap();
    assert_eq!(scan_all(&t), before, "flush/compaction layout must not change the scan");
    let c = store.storage_counters().unwrap();
    assert!(c.flushes.get() == 0, "reopen alone must not flush");
    t.put("zzz", "c", "after").unwrap();
    assert_eq!(scan_all(&t).len(), 101);
    std::fs::remove_dir_all(&dir).unwrap();
}
