//! Chaos end-to-end: the self-healing `RemoteD4m` client against a
//! `d4m serve` coordinator with a fault-injection proxy in between.
//!
//! The fault schedules are **scripted** (exact `(conn, dir, frame)`
//! targets), so every run exercises the same failure sequence: a
//! delayed request, a connection cut that eats a cursor page mid-scan,
//! and a corrupted frame on the resumed connection. The paged scan must
//! still complete **bit-identical** to an in-process scan, with the
//! healing visible in the client's counters. A non-idempotent write
//! whose reply is eaten must surface a typed `AmbiguousWrite` — and the
//! server must have applied it exactly once.

// Integration-test scaffolding: unwrap/expect on setup is idiomatic
// here; clippy.toml's disallowed-methods targets library code.
#![allow(clippy::disallowed_methods)]
use std::sync::Arc;
use std::time::Duration;

use d4m::connectors::TableQuery;
use d4m::coordinator::{D4mApi, D4mServer, Request};
use d4m::net::chaos::{ChaosOpts, ChaosProxy, Dir, Fault, ScriptedFault};
use d4m::net::{serve, NetOpts, RemoteD4m, RetryPolicy};
use d4m::pipeline::{PipelineConfig, TripleMsg};
use d4m::D4mError;

/// A 12-entry table: enough for a multi-page scan at 2 entries/page.
fn server_with_table(n: usize) -> Arc<D4mServer> {
    let s = Arc::new(D4mServer::with_engine(None));
    let triples: Vec<TripleMsg> = (0..n)
        .map(|i| (format!("r{i:02}"), format!("c{i:02}"), "1".into()))
        .collect();
    s.handle(Request::Ingest {
        table: "G".into(),
        triples,
        pipeline: PipelineConfig { num_workers: 2, ..Default::default() },
    })
    .unwrap();
    s
}

/// A retry policy tuned for tests: generous attempts, short backoff.
fn test_policy() -> RetryPolicy {
    RetryPolicy {
        max_attempts: 12,
        base_delay: Duration::from_millis(10),
        max_delay: Duration::from_millis(200),
        deadline: Some(Duration::from_secs(30)),
        ..Default::default()
    }
}

/// Drain a paged scan through the (possibly faulty) client.
fn drain_scan(c: &dyn D4mApi, page_entries: usize) -> Vec<TripleMsg> {
    let id = c.open_cursor("G", &TableQuery::all(), page_entries).expect("open cursor");
    let mut got = Vec::new();
    loop {
        let page = c.cursor_next(id).expect("cursor page");
        got.extend(page.triples);
        if page.done {
            break;
        }
    }
    c.cursor_close(id).expect("cursor close");
    got
}

/// With an empty schedule the proxy is a transparent relay: remote
/// answers through it are bit-identical and no faults are counted.
#[test]
fn passthrough_proxy_is_transparent() {
    let server = server_with_table(12);
    let mut handle = serve(server.clone(), "127.0.0.1:0", NetOpts::default()).expect("bind");
    let mut proxy = ChaosProxy::start(
        "127.0.0.1:0",
        &handle.addr().to_string(),
        ChaosOpts::default(),
    )
    .expect("proxy");

    let c = RemoteD4m::connect_with(&proxy.addr().to_string(), test_policy()).unwrap();
    let via_proxy = c.query("G", TableQuery::all()).unwrap();
    let direct = server.query("G", TableQuery::all()).unwrap();
    assert_eq!(via_proxy, direct);
    assert_eq!(drain_scan(&c, 5), drain_scan(server.as_ref(), 5));

    let stats = proxy.stats();
    assert!(stats.conns >= 1 && stats.frames > 0);
    assert_eq!(stats.faults, 0);
    assert_eq!(c.retry_count(), 0);
    assert_eq!(c.reconnect_count(), 0);

    drop(c);
    proxy.shutdown();
    handle.shutdown();
}

/// The tentpole scenario: a seeded/scripted fault schedule — one
/// delayed request frame, a connection cut that eats a cursor page
/// mid-scan, and a corrupted frame on the resumed connection — and the
/// paged remote scan still matches the in-process scan bit for bit,
/// via reconnect + cursor resume. The healing shows up in the client's
/// retry counters.
#[test]
fn scripted_faults_scan_is_bit_identical_via_resume() {
    let server = server_with_table(12);
    let mut handle = serve(server.clone(), "127.0.0.1:0", NetOpts::default()).expect("bind");

    // connection 0 (up): frame 0 = OpenCursor, frame 1+ = CursorNext
    // connection 0 (down): frame 0 = CursorOpened, frame 1+ = CursorPage
    let opts = ChaosOpts {
        scripted: vec![
            // latency spike on the first pull request
            ScriptedFault {
                conn: 0,
                dir: Dir::Up,
                frame: 1,
                fault: Fault::Delay { ms: 40 },
            },
            // eat the second CursorPage reply and cut the connection:
            // the client must reconnect and resume; the server replays
            // the lost page from its buffer
            ScriptedFault { conn: 0, dir: Dir::Down, frame: 2, fault: Fault::Cut },
            // on the resumed connection, corrupt the magic byte of the
            // next fresh page: guaranteed detection, second resume
            ScriptedFault {
                conn: 1,
                dir: Dir::Down,
                frame: 2,
                fault: Fault::CorruptByte { offset: 0, xor: 0xFF },
            },
        ],
        ..Default::default()
    };
    let mut proxy =
        ChaosProxy::start("127.0.0.1:0", &handle.addr().to_string(), opts).expect("proxy");

    let c = RemoteD4m::connect_with(&proxy.addr().to_string(), test_policy()).unwrap();
    let got = drain_scan(&c, 2);
    let want = drain_scan(server.as_ref(), 2);
    assert_eq!(got, want, "faulty-path scan diverged from in-process scan");

    // the healing actually happened (and is observable, as `d4m client
    // stats` prints these same counters)
    assert!(c.reconnect_count() >= 2, "expected 2+ reconnects, got {}", c.reconnect_count());
    assert!(
        c.cursor_resume_count() >= 2,
        "expected 2+ cursor resumes, got {}",
        c.cursor_resume_count()
    );
    assert!(c.retry_count() >= 2, "expected 2+ retries, got {}", c.retry_count());
    assert!(proxy.stats().faults >= 3, "proxy injected {} faults", proxy.stats().faults);

    // the explicit close on the final connection released the cursor
    assert_eq!(server.open_cursor_count(), 0);

    drop(c);
    proxy.shutdown();
    handle.shutdown();
}

/// A non-idempotent write whose reply is eaten surfaces a typed
/// `AmbiguousWrite` — and is **never** silently double-applied: the
/// server-side result table matches a single application exactly.
#[test]
fn interrupted_write_is_ambiguous_never_double_applied() {
    let server = server_with_table(12);
    let mut handle = serve(server.clone(), "127.0.0.1:0", NetOpts::default()).expect("bind");

    // eat the reply to the very first request on connection 0: the
    // server has executed the write by the time its reply frame reaches
    // the proxy, so cutting *here* is exactly the ambiguous window
    let opts = ChaosOpts {
        scripted: vec![ScriptedFault { conn: 0, dir: Dir::Down, frame: 0, fault: Fault::Cut }],
        ..Default::default()
    };
    let mut proxy =
        ChaosProxy::start("127.0.0.1:0", &handle.addr().to_string(), opts).expect("proxy");

    let c = RemoteD4m::connect_with(&proxy.addr().to_string(), test_policy()).unwrap();
    match c.tablemult("G", "G", "C") {
        Err(D4mError::AmbiguousWrite(_)) => {}
        other => panic!("expected AmbiguousWrite for an interrupted TableMult, got {other:?}"),
    }

    // single-apply check: an identical in-process server applying the
    // mult exactly once must agree with what the remote server holds
    let reference = server_with_table(12);
    reference.tablemult("G", "G", "C").unwrap();
    let want = reference.query("C", TableQuery::all()).unwrap();
    let got = server.query("C", TableQuery::all()).unwrap();
    assert_eq!(got, want, "interrupted write was applied more than once (or not at all)");

    // an idempotent call on the same client heals straight through
    assert_eq!(
        c.query("G", TableQuery::all()).unwrap(),
        server.query("G", TableQuery::all()).unwrap()
    );

    drop(c);
    proxy.shutdown();
    handle.shutdown();
}
