//! End-to-end tests for the network front-end: a live TCP server over a
//! shared `D4mServer`, driven by real `RemoteD4m` connections on
//! loopback.
//!
//! The load-bearing assertions (the acceptance criteria of the net
//! PRs): **4 concurrent remote clients issuing the same `TableQuery`
//! each get an answer bit-identical to the in-process
//! `D4mServer::handle` answer**; **one connection with 8 pipelined
//! in-flight requests completes all of them with out-of-order responses
//! correctly correlated by request id**; and **a remote paged scan over
//! a table larger than one page is bit-identical to the one-shot query
//! while every page respects the `page_entries` bound** — the remote
//! path adds transport, never semantics.

// Integration-test scaffolding: unwrap/expect on setup is idiomatic
// here; clippy.toml's disallowed-methods targets library code.
#![allow(clippy::disallowed_methods)]
use std::sync::Arc;
use std::time::Duration;

use d4m::assoc::KeySel;
use d4m::connectors::TableQuery;
use d4m::coordinator::{D4mApi, D4mServer, ExecHint, MultDest, Request, Response};
use d4m::net::{serve, NetOpts, RemoteD4m, RetryPolicy};
use d4m::pipeline::{PipelineConfig, TripleMsg};
use d4m::{D4mError, Plan};

/// Readiness-probe connect (the old fixed-interval `connect_retry`).
fn connect(addr: &str) -> RemoteD4m {
    RemoteD4m::connect_with(addr, RetryPolicy::probe(25, Duration::from_millis(100)))
        .expect("connect")
}

/// An in-process coordinator with the 4-edge demo graph ingested.
fn server_with_graph() -> Arc<D4mServer> {
    let s = Arc::new(D4mServer::with_engine(None));
    let triples: Vec<TripleMsg> = vec![
        ("a".into(), "b".into(), "1".into()),
        ("b".into(), "c".into(), "1".into()),
        ("a".into(), "c".into(), "1".into()),
        ("c".into(), "d".into(), "1".into()),
    ];
    s.handle(Request::Ingest {
        table: "G".into(),
        triples,
        pipeline: PipelineConfig { num_workers: 2, ..Default::default() },
    })
    .unwrap();
    s
}

/// Serve on an ephemeral loopback port; returns the handle and address.
fn spawn_net(server: Arc<D4mServer>) -> (d4m::net::NetHandle, String) {
    let handle = serve(server, "127.0.0.1:0", NetOpts::default()).expect("bind loopback");
    let addr = handle.addr().to_string();
    (handle, addr)
}

#[test]
fn four_concurrent_remote_clients_match_in_process_bit_for_bit() {
    let server = server_with_graph();
    let (mut handle, addr) = spawn_net(server.clone());

    // the queries every client will issue, spanning the pushdown forms
    let queries = [
        TableQuery::all(),
        TableQuery::all().cols(KeySel::keys(&["c"])),
        TableQuery::all().rows(KeySel::Range("a".into(), "b".into())),
        TableQuery::all().rows(KeySel::Prefix("a".into())).limit(2),
    ];

    // in-process reference answers
    let reference: Vec<_> = queries
        .iter()
        .map(|q| {
            server
                .handle(Request::Query { table: "G".into(), query: q.clone() })
                .unwrap()
                .into_assoc()
                .unwrap()
        })
        .collect();

    std::thread::scope(|s| {
        for client_id in 0..4 {
            let addr = addr.clone();
            let queries = &queries;
            let reference = &reference;
            s.spawn(move || {
                let c = connect(&addr);
                for _pass in 0..5 {
                    for (q, want) in queries.iter().zip(reference.iter()) {
                        let got = c.query("G", q.clone()).expect("remote query");
                        assert_eq!(
                            &got, want,
                            "client {client_id}: remote answer diverged from in-process"
                        );
                        // bit-identical includes the raw CSR arrays
                        assert_eq!(got.matrix(), want.matrix());
                    }
                }
            });
        }
    });

    handle.shutdown();
}

#[test]
fn remote_mirrors_every_coordinator_op() {
    let server = server_with_graph();
    let (mut handle, addr) = spawn_net(server.clone());
    let c = connect(&addr);

    // ping + tables
    c.ping().unwrap();
    let tables = c.list_tables().unwrap();
    assert!(tables.iter().any(|t| t == "G"), "tables: {tables:?}");

    // ingest through the wire, then query what was written
    c.create_table("H", vec![]).unwrap();
    let rep = c
        .ingest(
            "H",
            vec![("x".into(), "y".into(), "3".into())],
            PipelineConfig { num_workers: 1, ..Default::default() },
        )
        .unwrap();
    assert_eq!(rep.triples, 1);
    let h = c.query("H", TableQuery::all()).unwrap();
    assert_eq!(h.get("x", "y"), 3.0);

    // graph algorithms round-trip against the in-process answers
    let bfs_remote = c.bfs("G", &["a"], 2).unwrap();
    match server
        .handle(Request::Bfs { table: "G".into(), seeds: vec!["a".into()], hops: 2 })
        .unwrap()
    {
        Response::Distances(d) => assert_eq!(bfs_remote, d),
        other => panic!("unexpected {other:?}"),
    }

    let mult_remote = c.tablemult_client("G", "G", usize::MAX).unwrap();
    let mult_local = server
        .handle(Request::TableMult {
            a: "G".into(),
            b: "G".into(),
            dest: MultDest::Client,
            exec: ExecHint::Memory { limit: usize::MAX },
        })
        .unwrap()
        .into_assoc()
        .unwrap();
    assert_eq!(mult_remote, mult_local);

    let pr_remote = c.pagerank("G", Default::default()).unwrap();
    match server
        .handle(Request::PageRank { table: "G".into(), opts: Default::default() })
        .unwrap()
    {
        Response::Ranks(r) => assert_eq!(pr_remote, r),
        other => panic!("unexpected {other:?}"),
    }

    let stats = c.stats().unwrap();
    assert!(stats.iter().any(|s| s.name == "net.requests" && s.count > 0));
    assert!(stats.iter().any(|s| s.name == "query"));

    handle.shutdown();
}

#[test]
fn remote_errors_arrive_typed_not_as_panics() {
    let server = Arc::new(D4mServer::with_engine(None));
    let (mut handle, addr) = spawn_net(server);
    let c = connect(&addr);

    // unknown table: the coordinator's NotFound crosses the wire intact
    match c.query("nope", TableQuery::all()) {
        Err(D4mError::NotFound(msg)) => assert!(msg.contains("nope")),
        other => panic!("expected NotFound, got {other:?}"),
    }

    // memory wall: the structured MemoryLimit error round-trips
    c.create_table("G", vec![]).unwrap();
    c.ingest(
        "G",
        vec![("a".into(), "b".into(), "1".into()), ("b".into(), "c".into(), "1".into())],
        PipelineConfig { num_workers: 1, ..Default::default() },
    )
    .unwrap();
    match c.tablemult_client("G", "G", 10) {
        Err(D4mError::MemoryLimit { limit: 10, .. }) => {}
        other => panic!("expected MemoryLimit, got {other:?}"),
    }

    // the connection that errored keeps serving
    c.ping().unwrap();
    handle.shutdown();
}

#[test]
fn bad_frame_poisons_connection_not_server() {
    use std::io::{Read, Write};

    let server = server_with_graph();
    let (mut handle, addr) = spawn_net(server);

    // a raw socket sends a garbage header: the server must answer with a
    // framed error and close only this connection. (Exactly 8 bytes — a
    // full header — so the server consumes everything it was sent and
    // its close is a clean FIN, not an RST that could eat the reply.)
    let mut raw = std::net::TcpStream::connect(&addr).unwrap();
    raw.write_all(b"notd4m!!").unwrap();
    raw.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut reply = Vec::new();
    raw.read_to_end(&mut reply).ok(); // server closes after the error frame
    assert!(!reply.is_empty(), "expected a framed error before close");
    let payload = d4m::net::wire::read_frame(&mut &reply[..]).expect("framed error reply");
    match d4m::net::wire::decode_server_frame(&payload).expect("decodable reply") {
        (id, d4m::net::wire::ServerMsg::Reply(Err(e))) => {
            assert_eq!(id, d4m::net::wire::CONN_ERR_ID, "poison must use the reserved id");
            assert!(matches!(e, D4mError::Wire(_) | D4mError::Remote(_)), "got {e:?}");
        }
        other => panic!("expected an error reply, got {other:?}"),
    }

    // ...while a well-behaved client on a fresh connection still works
    let c = connect(&addr);
    assert_eq!(c.query("G", TableQuery::all()).unwrap().nnz(), 4);

    let stats = c.stats().unwrap();
    assert!(stats.iter().any(|s| s.name == "net.bad_frames" && s.count >= 1));
    handle.shutdown();
}

#[test]
fn client_initiated_shutdown_quiesces_server() {
    let server = server_with_graph();
    let (mut handle, addr) = spawn_net(server);

    let c = connect(&addr);
    c.shutdown_server().unwrap();

    // wait() returns because the accept loop exited and drained
    handle.wait();
    assert!(handle.is_shutting_down());

    // new connections are no longer served: either refused outright or
    // accepted by the dying listener and never answered
    match RemoteD4m::connect(&addr) {
        Err(_) => {}
        Ok(c2) => assert!(c2.ping().is_err(), "server answered after shutdown"),
    }
}

/// Acceptance criterion: 8 pipelined in-flight requests on ONE
/// connection, claimed newest-first so responses are consumed out of
/// submission order, every one correlated to the right request by id.
#[test]
fn pipelined_requests_correlate_out_of_order() {
    let server = server_with_graph();
    let (mut handle, addr) = spawn_net(server.clone());
    let c = connect(&addr);

    // two distinguishable request shapes, alternating
    let row_q = |k: &str| TableQuery::all().rows(KeySel::keys(&[k]));
    let want_a = server
        .handle(Request::Query { table: "G".into(), query: row_q("a") })
        .unwrap()
        .into_assoc()
        .unwrap();

    for _round in 0..5 {
        let mut ids: Vec<(u64, bool)> = Vec::new();
        for i in 0..8 {
            let expect_tables = i % 2 == 0;
            let req = if expect_tables {
                Request::ListTables
            } else {
                Request::Query { table: "G".into(), query: row_q("a") }
            };
            ids.push((c.submit(req).unwrap(), expect_tables));
        }
        // claim in reverse submission order: the earlier responses land
        // while we wait on the last id and must be parked + correlated
        for (id, expect_tables) in ids.into_iter().rev() {
            match c.wait(id).unwrap() {
                Response::Tables(ts) => {
                    assert!(expect_tables, "Tables answer correlated to a Query id");
                    assert!(ts.iter().any(|t| t == "G"));
                }
                Response::Assoc(a) => {
                    assert!(!expect_tables, "Assoc answer correlated to a ListTables id");
                    assert_eq!(a, want_a, "pipelined query answer diverged");
                }
                other => panic!("unexpected response {other:?}"),
            }
        }
    }
    // ids are claimable exactly once: a re-wait on a claimed id and a
    // wait on a never-submitted id both fail typed instead of hanging
    match c.wait(1) {
        Err(D4mError::InvalidArg(msg)) => assert!(msg.contains("not in flight")),
        other => panic!("double-wait should fail typed, got {other:?}"),
    }
    match c.wait(u64::MAX) {
        Err(D4mError::InvalidArg(msg)) => assert!(msg.contains("not in flight")),
        other => panic!("unknown-id wait should fail typed, got {other:?}"),
    }
    // a submitted-then-forgotten id is discarded, not parked forever
    let id = c.submit(Request::ListTables).unwrap();
    c.forget(id);
    match c.wait(id) {
        Err(D4mError::InvalidArg(_)) => {}
        other => panic!("forgotten-id wait should fail typed, got {other:?}"),
    }
    // and the connection stays healthy after all of it
    c.ping().unwrap();
    handle.shutdown();
}

/// Acceptance criterion: a remote paged scan over a table larger than
/// one page is bit-identical to the in-process one-shot query, and no
/// page exceeds `page_entries`.
#[test]
fn remote_scan_pages_bit_identical_and_bounded() {
    let server = Arc::new(D4mServer::with_engine(None));
    // 60 entries so a 7-entry page leaves many page boundaries
    let triples: Vec<TripleMsg> = (0..60)
        .map(|i| (format!("r{:02}", i % 12), format!("c{:02}", i / 12 * 5 + i % 5), "1".into()))
        .collect();
    server
        .handle(Request::Ingest {
            table: "G".into(),
            triples,
            pipeline: PipelineConfig { num_workers: 2, ..Default::default() },
        })
        .unwrap();
    let (mut handle, addr) = spawn_net(server.clone());

    let want = server
        .handle(Request::Query { table: "G".into(), query: TableQuery::all() })
        .unwrap()
        .into_assoc()
        .unwrap();
    assert!(want.nnz() > 7, "table must span several pages");

    let c = connect(&addr);
    let mut pages = 0usize;
    let mut triples: Vec<TripleMsg> = Vec::new();
    for page in c.scan_pages("G", TableQuery::all(), 7) {
        let p = page.expect("cursor page");
        assert!(p.len() <= 7, "page exceeded page_entries bound");
        pages += 1;
        triples.extend(p);
    }
    assert!(pages > 1, "expected multiple pages, got {pages}");
    let got = d4m::assoc::io::parse_triples(triples).unwrap();
    assert_eq!(got, want, "remote paged scan diverged from in-process query");
    assert_eq!(got.matrix(), want.matrix(), "CSR arrays must round-trip bit-identically");

    // into_assoc convenience takes the same path, selectors + limit hold
    let q = TableQuery::all().rows(KeySel::Prefix("r0".into())).limit(9);
    let want_sel = server
        .handle(Request::Query { table: "G".into(), query: q.clone() })
        .unwrap()
        .into_assoc()
        .unwrap();
    let got_sel = d4m::coordinator::ScanPages::new(&c, "G", q, 4).into_assoc().unwrap();
    assert_eq!(got_sel, want_sel);

    // drained cursors freed themselves server-side
    assert_eq!(server.open_cursor_count(), 0);
    handle.shutdown();
}

/// The plan-language acceptance criterion: a select → matmul → reduce
/// chain executes server-side in **one** round trip, bit-identical to
/// the sequential remote round trips, and the executor counters prove
/// zero intermediates were materialised. The same compiled plan also
/// drains through a streaming plan cursor page by page.
#[test]
fn remote_plan_one_round_trip_bit_identical_zero_intermediates() {
    let server = server_with_graph();
    let (mut handle, addr) = spawn_net(server.clone());
    let c = connect(&addr);

    // sequential: two Query round trips plus client-side matmul + sum
    let rows = KeySel::Range("a".into(), "b".into());
    let lhs = c.query("G", TableQuery::all().rows(rows.clone())).unwrap();
    let rhs = c.query("G", TableQuery::all()).unwrap();
    let want = lhs.matmul(&rhs).sum(2);

    // the same chain as one compiled plan: exactly one request crosses
    // the wire (net.requests is counted server-side, outside this client)
    let requests = |h: &d4m::net::NetHandle| {
        h.snapshots()
            .iter()
            .find(|s| s.name == "net.requests")
            .map(|s| s.count)
            .unwrap_or(0)
    };
    let ops = Plan::table("G")
        .select(rows, KeySel::All)
        .matmul(&Plan::table("G"))
        .sum(2)
        .compile()
        .unwrap();
    let n0 = requests(&handle);
    let (got, stats) = c.plan(&ops).unwrap();
    assert_eq!(requests(&handle) - n0, 1, "plan took more than one round trip");
    assert_eq!(got, want, "remote plan diverged from sequential remote ops");
    assert_eq!(got.matrix(), want.matrix(), "CSR arrays must match bit-for-bit");
    assert_eq!(stats.ops, 5);
    assert_eq!(stats.fused_selects, 1, "select was not folded into the scan");
    assert_eq!(stats.fused_reduces, 1, "reduce did not stream the matmul");
    assert_eq!(stats.intermediates, 0, "fused plan materialised an intermediate");

    // the compact text syntax takes the same path end to end
    let (got_expr, _) = c.plan_expr("sum(G('a,:,b,', ':') * G, 2)").unwrap();
    assert_eq!(got_expr, got);

    // the same ops through a remote plan cursor: page size 1 forces one
    // entry per page, reassembles bit-identically, and frees itself
    let mut pages = 0usize;
    let mut triples: Vec<TripleMsg> = Vec::new();
    for page in c.plan_pages(&ops, 1) {
        let p = page.expect("plan cursor page");
        assert!(p.len() <= 1, "page exceeded page_entries bound");
        pages += 1;
        triples.extend(p);
    }
    assert!(pages > 1, "expected multiple pages, got {pages}");
    let paged = d4m::assoc::io::parse_triples(triples).unwrap();
    assert_eq!(paged, got, "paged plan diverged from one-shot plan");
    assert_eq!(server.open_cursor_count(), 0, "drained plan cursor must free itself");
    handle.shutdown();
}

/// A dropped connection orphans its cursors into the resume-grace
/// window and the background sweep reaps them; an explicit CursorClose
/// releases immediately.
#[test]
fn cursor_lifecycle_across_connections() {
    let server = server_with_graph();
    let (mut handle, addr) = spawn_net(server.clone());

    let c = connect(&addr);
    let id = c.open_cursor("G", &TableQuery::all(), 2).unwrap();
    assert_eq!(server.open_cursor_count(), 1);
    let first = c.cursor_next(id).unwrap();
    assert!(first.triples.len() <= 2);
    assert!(!first.done);
    // explicit close releases the snapshot now
    c.cursor_close(id).unwrap();
    assert_eq!(server.open_cursor_count(), 0);
    // ...and the closed cursor is gone (typed error, connection healthy)
    match c.cursor_next(id) {
        Err(D4mError::NotFound(_)) => {}
        other => panic!("expected NotFound for a closed cursor, got {other:?}"),
    }
    c.ping().unwrap();

    // a second client's cursor is invisible to the first's owner scope,
    // and dropping that client's connection reaps it
    let c2 = connect(&addr);
    let id2 = c2.open_cursor("G", &TableQuery::all(), 1).unwrap();
    assert_eq!(server.open_cursor_count(), 1);
    match c.cursor_next(id2) {
        Err(D4mError::NotFound(_)) => {}
        other => panic!("cursor ownership leaked across connections: {other:?}"),
    }
    drop(c2); // connection closes; after the resume grace the sweep reaps
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while server.open_cursor_count() > 0 {
        assert!(
            std::time::Instant::now() < deadline,
            "dropped connection's cursor was never reaped"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    handle.shutdown();
}

/// A v1 frame against the current server draws one typed version error (the
/// reserved connection-error id), not a mid-stream decode failure.
#[test]
fn version_skew_is_one_typed_error() {
    use std::io::{Read, Write};

    let server = server_with_graph();
    let (mut handle, addr) = spawn_net(server);

    // a v1-shaped frame: magic, version 1, tiny payload
    let mut raw = std::net::TcpStream::connect(&addr).unwrap();
    let mut frame = Vec::new();
    frame.extend_from_slice(b"D4M");
    frame.push(1); // wire v1
    frame.extend_from_slice(&2u32.to_le_bytes());
    frame.extend_from_slice(&[0x01, 0x00]);
    raw.write_all(&frame).unwrap();
    raw.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut reply = Vec::new();
    raw.read_to_end(&mut reply).ok();
    assert!(!reply.is_empty(), "expected a framed version error before close");
    let payload = d4m::net::wire::read_frame(&mut &reply[..]).expect("framed reply");
    match d4m::net::wire::decode_server_frame(&payload).expect("decodable reply") {
        (id, d4m::net::wire::ServerMsg::Reply(Err(e))) => {
            assert_eq!(id, d4m::net::wire::CONN_ERR_ID);
            let msg = e.to_string();
            assert!(msg.contains("version"), "not a version error: {msg}");
        }
        other => panic!("expected an error reply, got {other:?}"),
    }
    handle.shutdown();
}

#[test]
fn bounded_pool_still_serves_under_conn_pressure() {
    let server = server_with_graph();
    let opts = NetOpts { max_conns: 2, ..Default::default() };
    let mut handle = serve(server, "127.0.0.1:0", opts).expect("bind");
    let addr = handle.addr().to_string();

    // 6 concurrent clients against a pool of 2: everyone is eventually
    // served — stragglers either wait out the accept queue or are shed
    // with a typed Overloaded that the healing client retries
    std::thread::scope(|s| {
        for _ in 0..6 {
            let addr = addr.clone();
            s.spawn(move || {
                let c = RemoteD4m::connect_with(
                    &addr,
                    RetryPolicy::probe(50, Duration::from_millis(100)),
                )
                .expect("connect");
                assert_eq!(c.query("G", TableQuery::all()).unwrap().nnz(), 4);
                // drop the client promptly to free the slot
            });
        }
    });
    handle.shutdown();
}

/// A saturated pool sheds new connections with a typed `Overloaded`
/// carrying a retry hint; a healing client rides the hint to success
/// once a slot frees up, and a no-retry client surfaces the error.
#[test]
fn saturated_pool_sheds_with_typed_overloaded() {
    let server = server_with_graph();
    let opts = NetOpts {
        max_conns: 1,
        shed_after: Duration::from_millis(50),
        ..Default::default()
    };
    let mut handle = serve(server, "127.0.0.1:0", opts).expect("bind");
    let addr = handle.addr().to_string();

    let holder = connect(&addr);
    holder.ping().unwrap(); // the one slot is now in use

    // no retries: the shed surfaces as a typed failure naming the overload
    let brittle = RemoteD4m::connect_with(
        &addr,
        RetryPolicy { max_attempts: 1, ..Default::default() },
    )
    .unwrap();
    match brittle.query("G", TableQuery::all()) {
        Err(D4mError::RetryExhausted { attempts, last }) => {
            assert_eq!(attempts, 1);
            assert!(last.contains("overloaded"), "unexpected last error: {last}");
        }
        other => panic!("expected RetryExhausted from a shed, got {other:?}"),
    }

    // a healing client retries the Overloaded hint until the slot frees
    let healing = RemoteD4m::connect_with(&addr, RetryPolicy::default()).unwrap();
    std::thread::scope(|s| {
        s.spawn(move || {
            std::thread::sleep(Duration::from_millis(200));
            drop(holder); // free the slot mid-retry
        });
        assert_eq!(healing.query("G", TableQuery::all()).unwrap().nnz(), 4);
        assert!(healing.retry_count() >= 1, "healing client never retried");
    });
    assert!(
        handle
            .snapshots()
            .iter()
            .any(|s| s.name == "net.sheds" && s.count >= 1),
        "server never recorded a shed"
    );
    handle.shutdown();
}

/// A slow-loris connection (valid header dribbled one byte at a tick)
/// is cut by the whole-frame deadline instead of pinning a pool slot,
/// and normal clients keep getting served while it dribbles.
#[test]
fn slow_loris_is_cut_without_pinning_the_pool() {
    use std::io::{Read, Write};

    let server = server_with_graph();
    let opts = NetOpts {
        max_conns: 2,
        idle_poll: Duration::from_millis(50),
        io_timeout: Duration::from_millis(500),
        ..Default::default()
    };
    let mut handle = serve(server, "127.0.0.1:0", opts).expect("bind");
    let addr = handle.addr().to_string();

    // a perfectly valid frame the loris will never finish sending: a
    // long table name keeps the payload far bigger than the deadline
    // allows at one byte per tick
    let req = d4m::net::wire::ClientMsg::Api(Request::Query {
        table: "x".repeat(256),
        query: TableQuery::all(),
    });
    let payload = d4m::net::wire::encode_client_frame(7, &req);
    let mut frame = Vec::new();
    frame.extend_from_slice(&d4m::net::wire::MAGIC);
    frame.push(d4m::net::wire::VERSION);
    frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    frame.extend_from_slice(&payload);

    let mut loris = std::net::TcpStream::connect(&addr).unwrap();
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        s.spawn(move || {
            let mut cut = false;
            for b in frame.iter() {
                if loris.write_all(&[*b]).is_err() {
                    cut = true; // server closed on us mid-dribble
                    break;
                }
                std::thread::sleep(Duration::from_millis(50));
                if t0.elapsed() > Duration::from_secs(8) {
                    break;
                }
            }
            if !cut {
                // writes can keep landing in the kernel buffer for a
                // while after the cut; the read side must still see it
                loris.set_read_timeout(Some(Duration::from_secs(5))).ok();
                let mut buf = [0u8; 16];
                cut = match loris.read(&mut buf) {
                    Ok(0) => true,
                    Err(e)
                        if e.kind() != std::io::ErrorKind::WouldBlock
                            && e.kind() != std::io::ErrorKind::TimedOut =>
                    {
                        true
                    }
                    _ => false,
                };
            }
            assert!(cut, "slow-loris connection was never cut");
            assert!(
                t0.elapsed() < Duration::from_secs(8),
                "loris outlived the io deadline by far"
            );
        });

        // meanwhile the other pool slot serves normal traffic promptly
        let c = connect(&addr);
        for _ in 0..5 {
            assert_eq!(c.query("G", TableQuery::all()).unwrap().nnz(), 4);
        }
    });
    handle.shutdown();
}
