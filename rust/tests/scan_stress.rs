//! Concurrent-reader-vs-writer stress for the snapshot-isolated,
//! streaming scan path (DESIGN.md §Snapshot/streaming read path).
//!
//! Writer threads mutate (puts, deletes, forced flushes — so scans race
//! memtable freezes and compactions) while reader threads stream
//! full-range scans. Every observed stream must be:
//!   * internally sorted (strictly increasing keys after versioning),
//!   * tombstone-consistent (no delete marker ever escapes the stack,
//!     and a deleted cell never resurrects an older value), and
//!   * bit-identical to a materialised scan of the *same* snapshot
//!     (the sequential lazy stream vs. the scoped-thread parallel
//!     collect must agree entry for entry).

// Integration-test scaffolding: unwrap/expect on setup is idiomatic
// here; clippy.toml's disallowed-methods targets library code.
#![allow(clippy::disallowed_methods)]
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use d4m::kvstore::{Entry, IterConfig, KvStore, RowRange, TabletConfig};

/// Tiny flush threshold so the stress scans race flush/compaction.
fn stress_store() -> KvStore {
    KvStore::with_config(TabletConfig { memtable_flush_bytes: 1 << 10, max_runs: 4 })
}

fn assert_stream_wellformed(entries: &[Entry]) {
    for w in entries.windows(2) {
        assert!(
            w[0].key < w[1].key,
            "stream out of order: {:?} !< {:?}",
            w[0].key,
            w[1].key
        );
    }
    assert!(
        entries.iter().all(|e| !e.tombstone),
        "tombstone leaked through the iterator stack"
    );
}

#[test]
fn concurrent_readers_vs_writers_stream_consistency() {
    let store = stress_store();
    // three tablets so multi-tablet merge + parallel collect are exercised
    let t = store.create_table("t", vec!["g".into(), "p".into()]).unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let scans_done = Arc::new(AtomicU64::new(0));
    let cfg = IterConfig::default();

    std::thread::scope(|s| {
        // writers: each owns a row prefix; puts with periodic deletes and
        // forced flushes so tombstones cross flush boundaries mid-stress
        for (w, prefix) in ["a", "h", "q"].into_iter().enumerate() {
            let t = t.clone();
            let stop = stop.clone();
            s.spawn(move || {
                let mut i = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let row = format!("{prefix}{:04}", i % 400);
                    t.put(&row, "c", &format!("{w}-{i}")).unwrap();
                    if i % 7 == 0 {
                        t.delete(&row, "c").unwrap();
                    }
                    if i % 89 == 0 {
                        t.flush().unwrap();
                    }
                    i += 1;
                }
            });
        }
        // readers: stream + materialise the SAME snapshot and compare
        for _ in 0..4 {
            let t = t.clone();
            let stop = stop.clone();
            let cfg = cfg.clone();
            let scans_done = scans_done.clone();
            s.spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    let snap = t.snapshot_range(&RowRange::all());
                    let streamed: Vec<Entry> = snap.stream(&RowRange::all(), &cfg).collect();
                    let materialised = snap.collect_entries(&RowRange::all(), &cfg);
                    assert_eq!(
                        streamed, materialised,
                        "stream and materialised scan of one snapshot diverged"
                    );
                    assert_stream_wellformed(&streamed);
                    scans_done.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
        std::thread::sleep(Duration::from_millis(300));
        stop.store(true, Ordering::Relaxed);
    });

    assert!(scans_done.load(Ordering::Relaxed) > 0, "readers never completed a scan");
    // quiesced: a final stream equals a final materialised scan
    let final_stream: Vec<Entry> = t.scan_stream(&RowRange::all(), &cfg).collect();
    let final_scan = t.scan(&RowRange::all(), &cfg);
    assert_eq!(final_stream, final_scan);
    assert_stream_wellformed(&final_stream);
}

#[test]
fn delete_across_flush_boundary_under_concurrent_streams() {
    // single-cell protocol: the writer repeatedly writes a generation,
    // flushes (so the value freezes into a run), then deletes (tombstone
    // lands in the fresh memtable, superseding a value in an older
    // layer). Readers must only ever observe the cell as absent or as
    // one of the written generation values — never an empty value, a
    // tombstone, or a stale generation next to its own delete.
    let store = stress_store();
    let t = store.create_table("t", vec![]).unwrap();
    let stop = Arc::new(AtomicBool::new(false));

    std::thread::scope(|s| {
        {
            let t = t.clone();
            let stop = stop.clone();
            s.spawn(move || {
                let mut generation = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    t.put("r", "c", &generation.to_string()).unwrap();
                    t.flush().unwrap();
                    t.delete("r", "c").unwrap();
                    if generation % 3 == 0 {
                        t.flush().unwrap(); // tombstone crosses the boundary too
                    }
                    generation += 1;
                }
            });
        }
        for _ in 0..3 {
            let t = t.clone();
            let stop = stop.clone();
            s.spawn(move || {
                let cfg = IterConfig::default();
                while !stop.load(Ordering::Relaxed) {
                    let seen: Vec<Entry> = t.scan_stream(&RowRange::all(), &cfg).collect();
                    assert!(seen.len() <= 1, "one cell can yield at most one entry");
                    if let Some(e) = seen.first() {
                        assert!(!e.tombstone, "tombstone escaped");
                        assert!(
                            e.value.parse::<u64>().is_ok(),
                            "observed non-generation value {:?}",
                            e.value
                        );
                    }
                }
            });
        }
        std::thread::sleep(Duration::from_millis(200));
        stop.store(true, Ordering::Relaxed);
    });

    // quiesced: the last mutation wins deterministically
    let final_scan = t.scan(&RowRange::all(), &IterConfig::default());
    assert!(
        final_scan.is_empty() || final_scan[0].value.parse::<u64>().is_ok(),
        "final state corrupt: {final_scan:?}"
    );
}

#[test]
fn open_streams_do_not_block_writers_or_each_other() {
    let store = stress_store();
    let t = store.create_table("t", vec!["m".into()]).unwrap();
    for i in 0..500 {
        t.put(&format!("a{i:04}"), "c", "1").unwrap();
        t.put(&format!("z{i:04}"), "c", "1").unwrap();
    }
    // open several streams and hold them un-consumed
    let cfg = IterConfig::default();
    let streams: Vec<_> = (0..4).map(|_| t.scan_stream(&RowRange::all(), &cfg)).collect();
    // writers (same thread — a held tablet lock would deadlock here)
    t.put("a9999", "c", "late").unwrap();
    t.delete("a0000", "c").unwrap();
    t.flush().unwrap();
    // each held stream still reads its pre-write snapshot
    for s in streams {
        let seen: Vec<Entry> = s.collect();
        assert_eq!(seen.len(), 1000, "snapshot must not see post-snapshot writes");
        assert!(!seen.iter().any(|e| e.value == "late"));
    }
    // and a fresh scan sees the mutations
    let now = t.scan(&RowRange::all(), &cfg);
    assert_eq!(now.len(), 1000); // +1 late, -1 deleted
    assert!(now.iter().any(|e| e.value == "late"));
}
