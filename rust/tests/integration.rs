//! Cross-module integration tests: every layer composed the way the
//! examples and the e2e driver use them.

// Integration-test scaffolding: unwrap/expect on setup is idiomatic
// here; clippy.toml's disallowed-methods targets library code.
#![allow(clippy::disallowed_methods)]
use std::sync::Arc;

use d4m::assoc::{Assoc, KeySel};
use d4m::connectors::{AccumuloConnector, D4mTableConfig, TableQuery};
use d4m::coordinator::{D4mApi, D4mServer};
use d4m::gen::{kronecker_assoc, kronecker_triples, vertex_key, KroneckerParams};
use d4m::graphulo::{self, ClientCtx, TableMultOpts};
use d4m::kvstore::{KvStore, RowRange};
use d4m::pipeline::{IngestPipeline, PipelineConfig};
use d4m::polystore::{Island, Polystore};

/// The full Figure-2 path on a small graph: pipeline ingest -> server
/// TableMult -> client TableMult -> equality.
#[test]
fn fig2_path_small() {
    let params = KroneckerParams::new(8, 8, 7);
    let server = D4mServer::with_engine(None);
    // the test drives the coordinator through the `D4mApi` trait — the
    // same calls a remote client would make
    let r = server
        .ingest(
            "G",
            kronecker_triples(&params),
            PipelineConfig { num_workers: 3, batch_size: 256, ..Default::default() },
        )
        .unwrap();
    assert_eq!(r.triples, params.num_edges());

    server.tablemult("G", "G", "C").unwrap();
    let server_c = graphulo::read_product(&server.store().table("C").unwrap()).unwrap();
    let client_c = server.tablemult_client("G", "G", usize::MAX).unwrap();
    assert_eq!(server_c.triples(), client_c.triples());
}

/// Ingested graph equals the generated assoc (via versioned overwrite of
/// duplicate edges the store keeps the *count* written by put_assoc).
#[test]
fn pipeline_roundtrip_matches_generator() {
    let params = KroneckerParams::new(8, 4, 3);
    let g = kronecker_assoc(&params);
    let acc = AccumuloConnector::new();
    let t = Arc::new(acc.bind("G", &D4mTableConfig::default()).unwrap());
    // route through the pipeline as string triples of the assoc
    let triples: Vec<(String, String, String)> = g
        .str_triples()
        .into_iter()
        .collect();
    IngestPipeline::new(t.clone(), PipelineConfig { num_workers: 4, ..Default::default() })
        .run(triples.into_iter())
        .unwrap();
    let back = t.get_assoc().unwrap();
    assert_eq!(g.triples(), back.triples());
}

/// Graphulo algorithm stack vs client baselines on a non-trivial graph.
#[test]
fn graphulo_suite_agrees_with_client() {
    let g = kronecker_assoc(&KroneckerParams::new(8, 6, 11));
    let store = Arc::new(KvStore::new());
    let acc = AccumuloConnector::with_store(store.clone());
    let t = acc.bind("G", &D4mTableConfig::default()).unwrap();
    t.put_assoc(&g).unwrap();

    // BFS
    let seeds = vec![vertex_key(0)];
    assert_eq!(
        graphulo::bfs_server(&t.main(), &seeds, 4),
        graphulo::bfs_assoc(&g, &seeds, 4)
    );

    // Jaccard
    let deg = t.degree_table().unwrap();
    let sj = graphulo::jaccard_server(&store, &t.main(), &deg, "J").unwrap();
    let cj = graphulo::jaccard_assoc(&g);
    assert_eq!(sj.nnz(), cj.nnz());
    for (a, b) in sj.triples().iter().zip(cj.triples().iter()) {
        assert!((a.2 - b.2).abs() < 1e-9);
    }

    // k-truss
    let sym = graphulo::symmetrise_table(&store, &t.main(), "S").unwrap();
    let skt = graphulo::ktruss_server(&store, &sym, 3, "K").unwrap();
    let ckt = graphulo::ktruss_assoc(&g, 3);
    assert_eq!(skt.triples(), ckt.triples());
}

/// The memory wall: the same client op succeeds with a large budget and
/// fails with a small one, while Graphulo completes under either.
#[test]
fn memory_wall_reproduction() {
    let g = kronecker_assoc(&KroneckerParams::new(9, 8, 13));
    let store = Arc::new(KvStore::new());
    let acc = AccumuloConnector::with_store(store.clone());
    let cfg = D4mTableConfig { transpose: false, degrees: false, ..Default::default() };
    let t = acc.bind("G", &cfg).unwrap();
    t.put_assoc(&g).unwrap();

    // client succeeds unlimited
    assert!(ClientCtx::default().table_mult(&t.main(), &t.main()).is_ok());
    // client fails with a tiny budget
    assert!(matches!(
        ClientCtx::with_limit(1 << 10).table_mult(&t.main(), &t.main()),
        Err(d4m::D4mError::MemoryLimit { .. })
    ));
    // graphulo completes regardless (bounded server memory)
    let c = store.create_table("C", vec![]).unwrap();
    let stats = graphulo::table_mult(&t.main(), &t.main(), &c, &TableMultOpts::default()).unwrap();
    assert!(stats.partial_products > 0);
}

/// Polystore CAST chain preserves data across all three engines, and the
/// D4M-schema column query works after the chain.
#[test]
fn polystore_chain() {
    let p = Polystore::new();
    let a = Assoc::from_triples(&[
        ("d1", "w|x", 2.0),
        ("d1", "w|y", 1.0),
        ("d2", "w|x", 3.0),
    ]);
    p.put(Island::Relational, "t0", &a).unwrap();
    p.cast(Island::Relational, "t0", Island::Text, "t1").unwrap();
    p.cast(Island::Text, "t1", Island::Array, "t2").unwrap();
    let back = p.get(Island::Array, "t2").unwrap();
    assert_eq!(a.triples(), back.triples());

    // column query through the text island's transpose table, via the
    // engine-generic T(:, c) surface
    let col = p
        .query(Island::Text, "t1", &TableQuery::all().cols(KeySel::keys(&["w|x"])))
        .unwrap();
    assert_eq!(col.nnz(), 2);

    // the same query answered by a different island must agree exactly
    // (unified-API conformance across engines)
    p.cast(Island::Text, "t1", Island::Relational, "t3").unwrap();
    let col_rel = p
        .query(Island::Relational, "t3", &TableQuery::all().cols(KeySel::keys(&["w|x"])))
        .unwrap();
    assert_eq!(col.triples(), col_rel.triples());
}

/// The coordinator's dense path (native blocked GEMM) agrees with CSR.
#[test]
fn dense_path_agrees_when_available() {
    let server = D4mServer::new();
    // the native dense engine is always attached — no artifact gating
    assert!(server.has_engine(), "default coordinator must carry the dense engine");
    // a dense-ish operand: co-occurrence of a tiny graph
    let g = kronecker_assoc(&KroneckerParams::new(7, 8, 17));
    let c = g.transpose().matmul(&g);
    let engine = server.engine().unwrap();
    let dense = d4m::runtime::blocks::assoc_at_b_dense(engine, &c, &c, 128).unwrap();
    let csr = c.transpose().matmul(&c);
    assert_eq!(dense.nnz(), csr.nnz());
    for t in csr.triples().iter().step_by(37) {
        let got = dense.get(&t.0, &t.1);
        assert!((got - t.2).abs() < 1e-2 * t.2.abs().max(1.0));
    }
}

/// Degree tables stay exact under concurrent pipeline ingest with
/// duplicate column keys (summing combiner across workers).
#[test]
fn degree_exactness_under_parallelism() {
    let acc = AccumuloConnector::new();
    let t = Arc::new(acc.bind("T", &D4mTableConfig::default()).unwrap());
    let triples: Vec<(String, String, String)> = (0..2_000)
        .map(|i| (format!("r{i:05}"), format!("c{:02}", i % 10), "1".to_string()))
        .collect();
    IngestPipeline::new(t.clone(), PipelineConfig { num_workers: 8, ..Default::default() })
        .run(triples.into_iter())
        .unwrap();
    for c in 0..10 {
        assert_eq!(t.degree(&format!("c{c:02}")).unwrap(), 200.0);
    }
}

/// Subsref on the server (row-range scans) matches client subsref.
#[test]
fn range_queries_match_subsref() {
    let g = kronecker_assoc(&KroneckerParams::new(8, 4, 23));
    let acc = AccumuloConnector::new();
    let t = acc.bind("G", &D4mTableConfig::default()).unwrap();
    t.put_assoc(&g).unwrap();
    let lo = vertex_key(20);
    let hi = vertex_key(200);
    let server = t
        .get_assoc_range(&RowRange::inclusive(lo.clone(), hi.clone()))
        .unwrap();
    let client = g.select_rows(&KeySel::Range(lo, hi));
    assert_eq!(server.triples(), client.triples());
}
