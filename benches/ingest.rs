//! Bench: **T-ingest-acc** (Kepner 2014, "100M inserts/sec") and
//! **T-ingest-scidb** (Samsi 2016, "~3M inserts/sec SciDB import").
//!
//! Accumulo group: ingest rate vs. number of parallel pipeline workers
//! and batch size — the paper's claim is near-linear scaling with
//! parallelism (their 100M/s needed 216 nodes; we reproduce the *scaling
//! shape* on threads).
//!
//! SciDB group: chunked array import rate vs. chunk size.

use std::sync::Arc;

use d4m::arraystore::{ArraySchema, ArrayStore};
use d4m::connectors::{AccumuloConnector, D4mTableConfig};
use d4m::gen::doc_word_triples;
use d4m::pipeline::{IngestPipeline, PipelineConfig};
use d4m::util::{fmt_rate, XorShift64};

fn accumulo_group(smoke: bool) {
    println!("# T-ingest-acc: pipeline ingest rate vs workers / batch size");
    println!(
        "{:<9} {:<9} {:>10} {:>12} {:>14} {:>14} {:>8}",
        "workers", "batch", "triples", "seconds", "logical", "physical", "stalls"
    );
    let docs = if smoke { 200 } else { 2_000 };
    let triples: Vec<(String, String, String)> = doc_word_triples(docs, 100, 5_000, 99)
        .into_iter()
        .collect();
    let workers_set: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4, 8] };
    let batch_set: &[usize] = if smoke { &[4096] } else { &[512, 4096, 16384] };
    for &workers in workers_set {
        for &batch in batch_set {
            let acc = AccumuloConnector::new();
            let t = Arc::new(acc.bind("T", &D4mTableConfig::default()).unwrap());
            let p = IngestPipeline::new(
                t,
                PipelineConfig {
                    num_workers: workers,
                    batch_size: batch,
                    queue_depth: 8,
                    shard_by_row: true,
                },
            );
            let rep = p.run(triples.iter().cloned()).unwrap();
            println!(
                "{:<9} {:<9} {:>10} {:>12.3} {:>14} {:>14} {:>8}",
                workers,
                batch,
                rep.triples,
                rep.elapsed.as_secs_f64(),
                fmt_rate(rep.rate),
                fmt_rate(rep.physical_rate),
                rep.backpressure_stalls
            );
        }
    }
}

fn scidb_group(smoke: bool) {
    println!("\n# T-ingest-scidb: array import rate vs chunk size");
    println!("{:<9} {:>10} {:>12} {:>14} {:>8}", "chunk", "cells", "seconds", "rate", "chunks");
    let n: u64 = if smoke { 1 << 16 } else { 1 << 20 };
    let side: u64 = 4096;
    let chunk_set: &[u64] = if smoke { &[256] } else { &[64, 128, 256, 512, 1024] };
    for &chunk in chunk_set {
        let store = ArrayStore::new();
        let arr = store.create(ArraySchema::new("ing", (side, side), chunk, &["val"])).unwrap();
        let mut rng = XorShift64::new(2016);
        let cells: Vec<(u64, u64, Vec<f64>)> = (0..n)
            .map(|_| (rng.below(side), rng.below(side), vec![rng.f64()]))
            .collect();
        let t0 = std::time::Instant::now();
        // batched, chunk-aligned import
        for batch in cells.chunks(65_536) {
            arr.put_batch(batch.to_vec()).unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{:<9} {:>10} {:>12.3} {:>14} {:>8}",
            chunk,
            arr.count(),
            dt,
            fmt_rate(n as f64 / dt),
            arr.num_chunks()
        );
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    accumulo_group(smoke);
    scidb_group(smoke);
}
