//! Bench: **T-ingest-acc** (Kepner 2014, "100M inserts/sec") and
//! **T-ingest-scidb** (Samsi 2016, "~3M inserts/sec SciDB import").
//!
//! Accumulo group: ingest rate vs. number of parallel pipeline workers
//! and batch size — the paper's claim is near-linear scaling with
//! parallelism (their 100M/s needed 216 nodes; we reproduce the *scaling
//! shape* on threads). Run twice: against the default in-memory store
//! and against the durable engine (WAL + on-disk runs), so the write-
//! ahead-logging overhead is a tracked trajectory, not folklore.
//!
//! SciDB group: chunked array import rate vs. chunk size.
//!
//! Machine-readable records are appended to `BENCH_ingest.json`;
//! `--smoke` runs the smallest sizes only (the CI regression probe).

// Bench/example/test scaffolding: unwrap/expect on setup is idiomatic
// here; clippy.toml's disallowed-methods targets library code.
#![allow(clippy::disallowed_methods)]
use std::path::Path;
use std::sync::Arc;

use d4m::arraystore::{ArraySchema, ArrayStore};
use d4m::connectors::{AccumuloConnector, D4mTableConfig};
use d4m::gen::doc_word_triples;
use d4m::kvstore::{KvStore, StorageConfig, TabletConfig};
use d4m::pipeline::{IngestPipeline, PipelineConfig};
use d4m::util::bench::{append_records, BenchRecord};
use d4m::util::{fmt_rate, XorShift64};

fn ingest_triples(smoke: bool) -> Vec<(String, String, String)> {
    let docs = if smoke { 200 } else { 2_000 };
    doc_word_triples(docs, 100, 5_000, 99).into_iter().collect()
}

fn run_pipeline(
    acc: &AccumuloConnector,
    triples: &[(String, String, String)],
    workers: usize,
    batch: usize,
) -> d4m::pipeline::IngestReport {
    let t = Arc::new(acc.bind("T", &D4mTableConfig::default()).unwrap());
    let p = IngestPipeline::new(
        t,
        PipelineConfig {
            num_workers: workers,
            batch_size: batch,
            queue_depth: 8,
            shard_by_row: true,
        },
    );
    p.run(triples.iter().cloned()).unwrap()
}

fn report_row(rep: &d4m::pipeline::IngestReport, workers: usize, batch: usize) {
    println!(
        "{:<9} {:<9} {:>10} {:>12.3} {:>14} {:>14} {:>8}",
        workers,
        batch,
        rep.triples,
        rep.elapsed.as_secs_f64(),
        fmt_rate(rep.rate),
        fmt_rate(rep.physical_rate),
        rep.backpressure_stalls
    );
}

fn accumulo_group(smoke: bool, records: &mut Vec<BenchRecord>) {
    println!("# T-ingest-acc: pipeline ingest rate vs workers / batch size");
    println!(
        "{:<9} {:<9} {:>10} {:>12} {:>14} {:>14} {:>8}",
        "workers", "batch", "triples", "seconds", "logical", "physical", "stalls"
    );
    let triples = ingest_triples(smoke);
    let workers_set: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4, 8] };
    let batch_set: &[usize] = if smoke { &[4096] } else { &[512, 4096, 16384] };
    for &workers in workers_set {
        for &batch in batch_set {
            let acc = AccumuloConnector::new();
            let rep = run_pipeline(&acc, &triples, workers, batch);
            report_row(&rep, workers, batch);
            records.push(BenchRecord::new(
                "ingest",
                triples.len(),
                &format!("mem-w{workers}-b{batch}"),
                rep.elapsed.as_secs_f64(),
                rep.triples as usize,
            ));
        }
    }
}

/// The same pipeline shape against the durable engine: every batch goes
/// through the per-table WAL before its memtable, flushes freeze into
/// on-disk runs, and the background compactor runs throughout — the
/// measured gap to the `mem-*` keys IS the durability tax.
fn durable_group(smoke: bool, records: &mut Vec<BenchRecord>) {
    println!("\n# T-ingest-wal: the same ingest through the durable engine");
    println!(
        "{:<9} {:<9} {:>10} {:>12} {:>14} {:>14} {:>8}",
        "workers", "batch", "triples", "seconds", "logical", "physical", "stalls"
    );
    let triples = ingest_triples(smoke);
    let workers_set: &[usize] = if smoke { &[2] } else { &[1, 4] };
    for &workers in workers_set {
        let dir = std::env::temp_dir().join(format!(
            "d4m-bench-ingest-{}-w{workers}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let store = Arc::new(
            KvStore::open(&dir, TabletConfig::default(), StorageConfig::default()).unwrap(),
        );
        let acc = AccumuloConnector::with_store(store.clone());
        let rep = run_pipeline(&acc, &triples, workers, 4096);
        report_row(&rep, workers, 4096);
        let c = store.storage_counters().unwrap();
        println!(
            "#   wal: {} bytes appended, {} fsyncs, {} flushes, {} compactions",
            c.wal_bytes_appended.get(),
            c.wal_fsyncs.get(),
            c.flushes.get(),
            c.compactions.get()
        );
        records.push(BenchRecord::new(
            "ingest",
            triples.len(),
            &format!("wal-w{workers}"),
            rep.elapsed.as_secs_f64(),
            rep.triples as usize,
        ));
        drop(acc);
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

fn scidb_group(smoke: bool, records: &mut Vec<BenchRecord>) {
    println!("\n# T-ingest-scidb: array import rate vs chunk size");
    println!("{:<9} {:>10} {:>12} {:>14} {:>8}", "chunk", "cells", "seconds", "rate", "chunks");
    let n: u64 = if smoke { 1 << 16 } else { 1 << 20 };
    let side: u64 = 4096;
    let chunk_set: &[u64] = if smoke { &[256] } else { &[64, 128, 256, 512, 1024] };
    for &chunk in chunk_set {
        let store = ArrayStore::new();
        let arr = store.create(ArraySchema::new("ing", (side, side), chunk, &["val"])).unwrap();
        let mut rng = XorShift64::new(2016);
        let cells: Vec<(u64, u64, Vec<f64>)> = (0..n)
            .map(|_| (rng.below(side), rng.below(side), vec![rng.f64()]))
            .collect();
        let t0 = std::time::Instant::now();
        // batched, chunk-aligned import
        for batch in cells.chunks(65_536) {
            arr.put_batch(batch.to_vec()).unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{:<9} {:>10} {:>12.3} {:>14} {:>8}",
            chunk,
            arr.count(),
            dt,
            fmt_rate(n as f64 / dt),
            arr.num_chunks()
        );
        records.push(BenchRecord::new(
            "ingest",
            n as usize,
            &format!("scidb-c{chunk}"),
            dt,
            n as usize,
        ));
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut records: Vec<BenchRecord> = Vec::new();
    accumulo_group(smoke, &mut records);
    durable_group(smoke, &mut records);
    scidb_group(smoke, &mut records);
    let out = Path::new("BENCH_ingest.json");
    match append_records(out, &records) {
        Ok(()) => println!("# appended {} records to {}", records.len(), out.display()),
        Err(e) => eprintln!("# failed to write {}: {e}", out.display()),
    }
}
