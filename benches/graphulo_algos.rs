//! Bench: **T-algos** — the Graphulo algorithm suite (Hutchison et al.
//! 2015/2016): BFS, Jaccard and k-truss, server-side (in-database) vs
//! the client-side D4M baseline, across Kronecker scales.
//!
//! The published shape: server-side is competitive while never
//! materialising the full operands client-side; the gap narrows (or
//! flips) as data grows and client memory pressure rises.

// Bench/example/test scaffolding: unwrap/expect on setup is idiomatic
// here; clippy.toml's disallowed-methods targets library code.
#![allow(clippy::disallowed_methods)]
use std::sync::Arc;
use std::time::Instant;

use d4m::connectors::{AccumuloConnector, D4mTableConfig};
use d4m::gen::{kronecker_assoc, vertex_key, KroneckerParams};
use d4m::graphulo;
use d4m::kvstore::KvStore;

struct Setup {
    store: Arc<KvStore>,
    table: d4m::connectors::D4mTable,
    graph: d4m::assoc::Assoc,
}

fn setup(scale: u32) -> Setup {
    let g = kronecker_assoc(&KroneckerParams::new(scale, 8, 0xA160));
    let store = Arc::new(KvStore::new());
    let acc = AccumuloConnector::with_store(store.clone());
    let t = acc.bind("G", &D4mTableConfig::default()).unwrap();
    t.put_assoc(&g).unwrap();
    Setup { store, table: t, graph: g }
}

fn bench(name: &str, scale: u32, nnz: usize, f: impl FnOnce()) {
    let t0 = Instant::now();
    f();
    println!("{:<8} {:<10} {:>10} {:>12.4}", scale, name, nnz, t0.elapsed().as_secs_f64());
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let scales: &[u32] = if smoke { &[9] } else { &[9, 10, 11, 12] };
    println!("# T-algos: Graphulo server-side vs D4M client-side algorithms");
    println!("{:<8} {:<10} {:>10} {:>12}", "scale", "algo", "nnz", "seconds");
    for &scale in scales {
        let s = setup(scale);
        let seeds = vec![vertex_key(0), vertex_key(1)];

        bench("bfs-srv", scale, s.graph.nnz(), || {
            std::hint::black_box(graphulo::bfs_server(&s.table.main(), &seeds, 3));
        });
        bench("bfs-cli", scale, s.graph.nnz(), || {
            std::hint::black_box(graphulo::bfs_assoc(&s.graph, &seeds, 3));
        });

        let deg = s.table.degree_table().unwrap();
        bench("jac-srv", scale, s.graph.nnz(), || {
            std::hint::black_box(
                graphulo::jaccard_server(&s.store, &s.table.main(), &deg, "J").unwrap(),
            );
        });
        bench("jac-cli", scale, s.graph.nnz(), || {
            std::hint::black_box(graphulo::jaccard_assoc(&s.graph));
        });

        // k-truss is the heavy one; keep it to the smaller scales
        if scale <= 10 {
            bench("kt3-srv", scale, s.graph.nnz(), || {
                let sym = graphulo::symmetrise_table(&s.store, &s.table.main(), "Gs").unwrap();
                std::hint::black_box(graphulo::ktruss_server(&s.store, &sym, 3, "KT").unwrap());
            });
            bench("kt3-cli", scale, s.graph.nnz(), || {
                std::hint::black_box(graphulo::ktruss_assoc(&s.graph, 3));
            });
        }
    }
}
