//! Bench: the network front-end — loopback round-trip latency,
//! pipelined single-connection throughput, concurrent remote-scan
//! throughput, and paged cursor scans: the client↔server paths the D4M
//! papers measure ("Database Operations in D4M.jl").
//!
//! Scenarios (op = "net", n = stored edges):
//!   roundtrip   — one client, single-row queries back-to-back (one in
//!                 flight); entries_per_sec is *requests* per second
//!   pipelined8  — same single-row queries on ONE connection with 8 in
//!                 flight (submit/wait pipelining); entries_per_sec is
//!                 requests per second — the multiplexing win over
//!                 `roundtrip` is the headline of wire v2
//!   concurrent4 — 4 clients on 4 connections, full-table queries;
//!                 aggregate received entries per second (the remote
//!                 twin of scan.rs's concurrent4)
//!   paged       — one client draining the full table through a scan
//!                 cursor (512-entry pages); received entries per second
//!   plan-seq    — a select → matmul → sum chain the pre-plan way: two
//!                 Query round trips per pass (the right operand is the
//!                 whole table) plus client-side matmul + sum; result
//!                 entries per second
//!   plan        — the same chain compiled to ONE `Request::Plan`: the
//!                 expression executes server-side with the select folded
//!                 into the scan and the reduce streamed through the
//!                 contraction, so only the small result crosses the
//!                 wire; bit-identical to plan-seq by assertion
//!   degraded    — the same paged drain through a fault-injection proxy
//!                 cutting ~1% of frames: the self-healing client
//!                 reconnects and resumes the cursor, so the measured
//!                 rate is the degraded-mode trajectory (still
//!                 bit-complete — the drained entry count must match
//!                 the clean paged leg)
//!
//! Records append to `BENCH_net.json`; `--smoke` runs the smallest size
//! only (the CI regression probe checked by tools/bench_check.py).

// Bench/example/test scaffolding: unwrap/expect on setup is idiomatic
// here; clippy.toml's disallowed-methods targets library code.
#![allow(clippy::disallowed_methods)]
use std::collections::VecDeque;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use d4m::assoc::KeySel;
use d4m::connectors::TableQuery;
use d4m::coordinator::{D4mApi, D4mServer, Request};
use d4m::gen::{kronecker_triples, vertex_key, KroneckerParams};
use d4m::net::chaos::{ChaosOpts, ChaosProxy, Profile};
use d4m::net::{serve, NetOpts, RemoteD4m, RetryPolicy};
use d4m::pipeline::PipelineConfig;
use d4m::util::bench::{append_records, BenchRecord};
use d4m::util::fmt_rate;
use d4m::Plan;

const CLIENTS: usize = 4;
const INFLIGHT: usize = 8;
const PAGE_ENTRIES: usize = 512;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let scales: &[u32] = if smoke { &[8] } else { &[10, 12] };
    let (roundtrips, passes) = if smoke { (500, 2) } else { (2000, 4) };
    let mut records: Vec<BenchRecord> = Vec::new();
    println!("# net front-end: round-trip / pipelined / concurrent / paged remote scans");
    println!("{:<10} {:<14} {:>10} {:>12} {:>14}", "n", "mode", "entries", "seconds", "rate");

    for &scale in scales {
        let server = Arc::new(D4mServer::with_engine(None));
        let triples = kronecker_triples(&KroneckerParams::new(scale, 16, 20170710));
        let n = triples.len();
        server
            .handle(Request::Ingest {
                table: "G".into(),
                triples,
                pipeline: PipelineConfig { num_workers: 4, ..Default::default() },
            })
            .expect("ingest");
        let mut handle = serve(server, "127.0.0.1:0", NetOpts::default()).expect("bind loopback");
        let addr = handle.addr().to_string();

        // -- single-client round-trip latency (tiny frames, 1 in flight)
        let c = RemoteD4m::connect_with(&addr, RetryPolicy::probe(25, Duration::from_millis(100)))
            .expect("connect");
        let probe = vertex_key(1);
        let q = TableQuery::all().rows(KeySel::keys(&[probe.as_str()]));
        let t0 = Instant::now();
        for _ in 0..roundtrips {
            let _ = c.query("G", q.clone()).expect("query");
        }
        let dt = t0.elapsed().as_secs_f64();
        report(&mut records, n, "roundtrip", dt, roundtrips);

        // -- the same requests, pipelined 8-deep on the same connection
        let t1 = Instant::now();
        let mut window: VecDeque<u64> = VecDeque::with_capacity(INFLIGHT);
        let mut issued = 0usize;
        while issued < roundtrips || !window.is_empty() {
            while window.len() < INFLIGHT && issued < roundtrips {
                let id = c
                    .submit(Request::Query { table: "G".into(), query: q.clone() })
                    .expect("submit");
                window.push_back(id);
                issued += 1;
            }
            let id = window.pop_front().expect("window non-empty");
            let _ = c.wait(id).expect("wait").into_assoc().expect("assoc");
        }
        let dt = t1.elapsed().as_secs_f64();
        report(&mut records, n, "pipelined8", dt, roundtrips);

        // -- 4 concurrent clients, full-table scans
        let t2 = Instant::now();
        let mut total = 0usize;
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..CLIENTS)
                .map(|_| {
                    let addr = addr.clone();
                    s.spawn(move || {
                        let c = RemoteD4m::connect_with(
                            &addr,
                            RetryPolicy::probe(25, Duration::from_millis(100)),
                        )
                        .expect("connect");
                        let mut got = 0usize;
                        for _ in 0..passes {
                            got += c.query("G", TableQuery::all()).expect("scan").nnz();
                        }
                        got
                    })
                })
                .collect();
            for h in handles {
                total += h.join().expect("client thread");
            }
        });
        let dt = t2.elapsed().as_secs_f64();
        report(&mut records, n, "concurrent4", dt, total);

        // -- paged cursor scan of the whole table, one client
        let t3 = Instant::now();
        let mut paged_total = 0usize;
        for _ in 0..passes {
            for page in c.scan_pages("G", TableQuery::all(), PAGE_ENTRIES) {
                paged_total += page.expect("cursor page").len();
            }
        }
        let dt = t3.elapsed().as_secs_f64();
        report(&mut records, n, "paged", dt, paged_total);

        // -- the expression-language legs: a select → matmul → sum chain,
        // first as sequential round trips (the full right operand crosses
        // the wire every pass), then as one compiled server-side plan
        let range = KeySel::Range(vertex_key(0), vertex_key(63));
        let sel_q = TableQuery::all().rows(range.clone());
        let t4 = Instant::now();
        let mut seq_entries = 0usize;
        let mut seq_last = None;
        for _ in 0..passes {
            let a = c.query("G", sel_q.clone()).expect("seq select query");
            let g = c.query("G", TableQuery::all()).expect("seq full query");
            let r = a.matmul(&g).sum(2);
            seq_entries += r.nnz();
            seq_last = Some(r);
        }
        let dt = t4.elapsed().as_secs_f64();
        report(&mut records, n, "plan-seq", dt, seq_entries);

        let ops = Plan::table("G")
            .select(range, KeySel::All)
            .matmul(&Plan::table("G"))
            .sum(2)
            .compile()
            .expect("compile plan");
        let t5 = Instant::now();
        let mut plan_entries = 0usize;
        let mut plan_last = None;
        for _ in 0..passes {
            let (r, _) = c.plan(&ops).expect("plan");
            plan_entries += r.nnz();
            plan_last = Some(r);
        }
        let dt = t5.elapsed().as_secs_f64();
        assert_eq!(plan_last, seq_last, "plan leg diverged from sequential leg");
        report(&mut records, n, "plan", dt, plan_entries);

        // -- the same paged drain through a faulty link: ~1% of frames
        // cut the connection; the healing client reconnects and resumes
        let mut proxy = ChaosProxy::start(
            "127.0.0.1:0",
            &addr,
            ChaosOpts { profile: Profile::Drop { rate: 0.01 }, ..Default::default() },
        )
        .expect("chaos proxy");
        let heal = RetryPolicy {
            max_attempts: 16,
            base_delay: Duration::from_millis(5),
            max_delay: Duration::from_millis(100),
            deadline: Some(Duration::from_secs(120)),
            ..Default::default()
        };
        let cd =
            RemoteD4m::connect_with(&proxy.addr().to_string(), heal).expect("connect degraded");
        let t4 = Instant::now();
        let mut degraded_total = 0usize;
        for _ in 0..passes {
            for page in cd.scan_pages("G", TableQuery::all(), PAGE_ENTRIES) {
                degraded_total += page.expect("cursor page").len();
            }
        }
        let dt = t4.elapsed().as_secs_f64();
        assert_eq!(
            degraded_total, paged_total,
            "degraded scan dropped entries despite healing"
        );
        println!(
            "# degraded healing: {} retries, {} reconnects, {} cursor resumes, {} faults injected",
            cd.retry_count(),
            cd.reconnect_count(),
            cd.cursor_resume_count(),
            proxy.stats().faults
        );
        report(&mut records, n, "degraded", dt, degraded_total);
        drop(cd);
        proxy.shutdown();

        handle.shutdown();
    }

    let out = Path::new("BENCH_net.json");
    match append_records(out, &records) {
        Ok(()) => println!("# appended {} records to {}", records.len(), out.display()),
        Err(e) => eprintln!("# failed to write {}: {e}", out.display()),
    }
}

fn report(records: &mut Vec<BenchRecord>, n: usize, mode: &str, dt: f64, entries: usize) {
    println!(
        "{:<10} {:<14} {:>10} {:>12.3} {:>14}",
        n,
        mode,
        entries,
        dt,
        fmt_rate(entries as f64 / dt)
    );
    records.push(BenchRecord::new("net", n, mode, dt, entries));
}
