//! Bench: **T-jl** — the D4M.jl vs MATLAB D4M comparison (Chen et al.
//! 2016). The published result: same API, different implementation
//! maturity; the new implementation is comparable and sometimes faster.
//!
//! We reproduce the comparison *shape* with two interchangeable backends
//! of the identical op suite:
//!   naive — BTreeMap-of-cells interpreter style (MATLAB-class stand-in)
//!   csr   — sorted-key + CSR backend (the tuned implementation)
//!
//! Ops: construct, add, elem-mult, matmul, transpose, subsref-range.
//!
//! Besides the human-readable table, every run appends machine-readable
//! records (op, n, backend, seconds, entries/sec) to `BENCH_assoc.json`
//! so the trajectory is diffable across commits. `--smoke` runs the
//! smallest size only (the CI regression probe).

// Bench/example/test scaffolding: unwrap/expect on setup is idiomatic
// here; clippy.toml's disallowed-methods targets library code.
#![allow(clippy::disallowed_methods)]
use std::path::Path;
use std::time::Instant;

use d4m::assoc::kernel::KernelConfig;
use d4m::assoc::naive::NaiveAssoc;
use d4m::assoc::{Assoc, KeySel};
use d4m::util::bench::{append_records, BenchRecord};
use d4m::util::XorShift64;

fn rand_triples(n: usize, keyspace: u64, seed: u64) -> Vec<(String, String, f64)> {
    let mut rng = XorShift64::new(seed);
    (0..n)
        .map(|_| {
            (
                format!("r{:06}", rng.below(keyspace)),
                format!("c{:06}", rng.below(keyspace)),
                (rng.below(9) + 1) as f64,
            )
        })
        .collect()
}

fn time_op(f: impl FnOnce()) -> f64 {
    let t0 = Instant::now();
    f();
    t0.elapsed().as_secs_f64()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let exps: &[u32] = if smoke { &[10] } else { &[10, 12, 14, 16] };
    let mut records: Vec<BenchRecord> = Vec::new();

    println!("# T-jl: identical op suite on naive (MATLAB-class) vs csr (tuned) backends");
    println!(
        "{:<8} {:<12} {:>12} {:>12} {:>9}",
        "n", "op", "naive(s)", "csr(s)", "speedup"
    );
    for &exp in exps {
        let n = 1usize << exp;
        let keyspace = (n as u64 / 2).max(16);
        let t1 = rand_triples(n, keyspace, 1);
        let t2 = rand_triples(n, keyspace, 2);

        // construct
        let dt_naive = time_op(|| {
            std::hint::black_box(NaiveAssoc::from_triples(&t1));
        });
        let dt_csr = time_op(|| {
            std::hint::black_box(Assoc::from_triples(&t1));
        });
        report(&mut records, n, "construct", dt_naive, dt_csr);

        let na = NaiveAssoc::from_triples(&t1);
        let nb = NaiveAssoc::from_triples(&t2);
        let ca = Assoc::from_triples(&t1);
        let cb = Assoc::from_triples(&t2);

        let dt_naive = time_op(|| {
            std::hint::black_box(na.add(&nb));
        });
        let dt_csr = time_op(|| {
            std::hint::black_box(ca.add(&cb));
        });
        report(&mut records, n, "add", dt_naive, dt_csr);

        let dt_naive = time_op(|| {
            std::hint::black_box(na.elem_mult(&nb));
        });
        let dt_csr = time_op(|| {
            std::hint::black_box(ca.elem_mult(&cb));
        });
        report(&mut records, n, "elem-mult", dt_naive, dt_csr);

        let dt_naive = time_op(|| {
            std::hint::black_box(na.matmul(&nb));
        });
        let serial = KernelConfig::detect().with_threads(1);
        let dt_csr = time_op(|| {
            std::hint::black_box(ca.matmul_with(&cb, &serial));
        });
        report(&mut records, n, "matmul", dt_naive, dt_csr);

        let dt_naive = time_op(|| {
            std::hint::black_box(na.transpose());
        });
        let dt_csr = time_op(|| {
            std::hint::black_box(ca.transpose());
        });
        report(&mut records, n, "transpose", dt_naive, dt_csr);

        let lo = format!("r{:06}", keyspace / 4);
        let hi = format!("r{:06}", keyspace / 2);
        let dt_naive = time_op(|| {
            std::hint::black_box(na.select_row_range(&lo, &hi));
        });
        let dt_csr = time_op(|| {
            std::hint::black_box(ca.select_rows(&KeySel::Range(lo.clone(), hi.clone())));
        });
        report(&mut records, n, "subsref", dt_naive, dt_csr);
    }

    kernel_legs(&mut records, smoke);

    let out = Path::new("BENCH_assoc.json");
    match append_records(out, &records) {
        Ok(()) => println!("# appended {} records to {}", records.len(), out.display()),
        Err(e) => eprintln!("# failed to write {}: {e}", out.display()),
    }
}

/// Parallel-kernel legs: the same SpGEMM on serial / par{N} / blocked
/// kernels over a denser operand (the random T-jl triples rarely clear
/// the parallel cutoff). `N` is the detected thread count, so the CI
/// runner's `D4M_KERNEL_THREADS=2` produces a stable `par2` key.
fn kernel_legs(records: &mut Vec<BenchRecord>, smoke: bool) {
    let edge = if smoke { 1usize << 11 } else { 1usize << 12 };
    let per_row = 24;
    let t1 = rand_triples(edge * per_row, edge as u64, 11);
    let t2 = rand_triples(edge * per_row, edge as u64, 12);
    let a = Assoc::from_triples(&t1);
    let b = Assoc::from_triples(&t2);
    let detect = KernelConfig::detect();
    let par_label = format!("par{}", detect.threads);
    let blocked = KernelConfig {
        tile_cols: 512,
        blocked_row_flops: 0,
        ..detect
    };
    let legs: &[(&str, KernelConfig)] = &[
        ("serial", detect.with_threads(1)),
        (par_label.as_str(), detect),
        ("blocked", blocked),
    ];
    println!(
        "# parallel kernel legs: matmul on {} x {} operands ({} nnz each)",
        edge,
        edge,
        a.nnz()
    );
    for (backend, cfg) in legs {
        // min of 3 reps: one-shot timings are too noisy for the 40% gate
        let mut best = f64::MAX;
        let mut out_nnz = 0usize;
        for _ in 0..3 {
            let dt = time_op(|| {
                out_nnz = std::hint::black_box(a.matmul_with(&b, cfg)).nnz();
            });
            best = best.min(dt);
        }
        println!(
            "{:<8} {:<12} {:>12.5}s  {:>12} out-nnz  [{}]",
            edge, "matmul", best, out_nnz, backend
        );
        records.push(BenchRecord::new("matmul", edge, backend, best, out_nnz));
    }
}

fn report(records: &mut Vec<BenchRecord>, n: usize, op: &str, naive: f64, csr: f64) {
    println!(
        "{:<8} {:<12} {:>12.5} {:>12.5} {:>8.1}x",
        n,
        op,
        naive,
        csr,
        naive / csr.max(1e-12)
    );
    records.push(BenchRecord::new(op, n, "naive", naive, n));
    records.push(BenchRecord::new(op, n, "csr", csr, n));
}
