//! Ablation bench — isolates the §Perf design choices recorded in
//! EXPERIMENTS.md so each claim regenerates independently:
//!
//!   A1. TableMult partial-sum combiner cap (0 = write-through, then
//!       2^16 … 2^22) — the bounded server cache vs store round-trips.
//!   A2. Tablet compaction policy: size-tiered (ship) vs major-on-every-
//!       threshold (the naive merge-all this repo replaced).
//!   A3. BatchWriter batch size on the raw store write path.

// Bench/example/test scaffolding: unwrap/expect on setup is idiomatic
// here; clippy.toml's disallowed-methods targets library code.
#![allow(clippy::disallowed_methods)]
use std::sync::Arc;
use std::time::Instant;

use d4m::connectors::{AccumuloConnector, D4mTableConfig};
use d4m::gen::{kronecker_assoc, KroneckerParams};
use d4m::graphulo::{table_mult, TableMultOpts};
use d4m::kvstore::{Entry, Key, KvStore, TabletConfig, WriterConfig};
use d4m::util::fmt_rate;

fn ablate_combiner_cap(smoke: bool) {
    let scale = if smoke { 9 } else { 11 };
    println!("# A1: TableMult combiner cap (SCALE-{scale} Kronecker, ef=16)");
    println!("{:<12} {:>10} {:>12}", "cap", "seconds", "rate");
    let g = kronecker_assoc(&KroneckerParams::new(scale, 16, 20170710));
    let caps: &[usize] = if smoke { &[0, 1 << 18] } else { &[0, 1 << 16, 1 << 18, 1 << 20, 1 << 22] };
    for &cap in caps {
        let store = Arc::new(KvStore::new());
        let acc = AccumuloConnector::with_store(store.clone());
        let cfg = D4mTableConfig { transpose: false, degrees: false, ..Default::default() };
        let t = acc.bind("A", &cfg).unwrap();
        t.put_assoc(&g).unwrap();
        let c = store.ensure_table("C", vec![]).unwrap();
        let opts = TableMultOpts { combiner_cap: cap, ..Default::default() };
        let t0 = Instant::now();
        let stats = table_mult(&t.main(), &t.main(), &c, &opts).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{:<12} {:>10.3} {:>12}",
            cap,
            dt,
            fmt_rate(stats.partial_products as f64 / dt)
        );
    }
}

fn ablate_compaction(smoke: bool) {
    let n: u64 = if smoke { 60_000 } else { 600_000 };
    println!("\n# A2: compaction policy on a {n}-entry write burst");
    println!("{:<12} {:>10} {:>12} {:>12}", "policy", "seconds", "rate", "compactions");
    let entries: Vec<Entry> = (0..n)
        .map(|i| {
            Entry::new(
                Key::cell(format!("r{:07}", i % 100_000), format!("c{:03}", i % 500), i),
                "1",
            )
        })
        .collect();
    // size-tiered (ship): max_runs 8, merge small half
    for (name, cfg) in [
        ("tiered", TabletConfig { memtable_flush_bytes: 1 << 20, max_runs: 8 }),
        // "major-ish": force frequent full merges by keeping max_runs tiny
        ("eager", TabletConfig { memtable_flush_bytes: 1 << 20, max_runs: 2 }),
        ("no-compact", TabletConfig { memtable_flush_bytes: 1 << 20, max_runs: usize::MAX }),
    ] {
        let store = KvStore::with_config(cfg);
        let t = store.create_table("t", vec![]).unwrap();
        let t0 = Instant::now();
        t.put_batch(entries.clone()).unwrap();
        t.flush().unwrap();
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{:<12} {:>10.3} {:>12} {:>12}",
            name,
            dt,
            fmt_rate(entries.len() as f64 / dt),
            "-"
        );
    }
}

fn ablate_batch_size(smoke: bool) {
    let n: u64 = if smoke { 30_000 } else { 300_000 };
    println!("\n# A3: BatchWriter batch size, {n} writes through one writer");
    println!("{:<12} {:>10} {:>12}", "max_batch", "seconds", "rate");
    let batches: &[usize] = if smoke { &[1_000, 10_000] } else { &[100, 1_000, 10_000, 100_000] };
    for &batch in batches {
        let store = KvStore::new();
        let t = store.create_table("t", vec![]).unwrap();
        let mut w = d4m::kvstore::BatchWriter::new(
            t.clone(),
            WriterConfig { max_batch: batch, max_bytes: usize::MAX },
        );
        let t0 = Instant::now();
        for i in 0..n {
            w.put(&format!("r{:07}", i % 50_000), "c", "1").unwrap();
        }
        w.flush().unwrap();
        let dt = t0.elapsed().as_secs_f64();
        println!("{:<12} {:>10.3} {:>12}", batch, dt, fmt_rate(n as f64 / dt));
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    ablate_combiner_cap(smoke);
    ablate_compaction(smoke);
    ablate_batch_size(smoke);
}
