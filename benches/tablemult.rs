//! Bench: **Figure 2** — Graphulo vs. D4M TableMult scaling.
//!
//! For each Kronecker SCALE, runs C = A^T A three ways:
//!   graphulo  — server-side streaming TableMult (bounded memory)
//!   par2      — the same server-side TableMult sharded across 2 workers
//!   d4m       — client-side assoc matmul under a RAM budget
//!   d4m-dense — client-side path through the in-crate blocked dense
//!               GEMM (only when density makes it sensible)
//!
//! Output: one row per (SCALE, mode) with rate in partial products/sec.
//! The paper's shape to reproduce: graphulo ≈ d4m at small scale, d4m
//! hits the memory wall (OOM) at large scale while graphulo continues.
//!
//! Machine-readable records (op = "tablemult", n = edges, backend = mode)
//! are appended to `BENCH_assoc.json`; `--smoke` runs the two smallest
//! scales only (the CI regression probe).

// Bench/example/test scaffolding: unwrap/expect on setup is idiomatic
// here; clippy.toml's disallowed-methods targets library code.
#![allow(clippy::disallowed_methods)]
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use d4m::connectors::{AccumuloConnector, D4mTableConfig};
use d4m::gen::{kronecker_assoc, KroneckerParams};
use d4m::graphulo::{self, ClientCtx, TableMultOpts};
use d4m::kvstore::KvStore;
use d4m::util::bench::{append_records, BenchRecord};
use d4m::util::{fmt_bytes, fmt_rate};

const CLIENT_MEM_LIMIT: usize = 24 << 20;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let scales: &[u32] = if smoke { &[8, 9] } else { &[8, 9, 10, 11, 12, 13] };
    let mut records: Vec<BenchRecord> = Vec::new();
    println!("# Figure 2: Graphulo vs D4M TableMult scaling");
    println!("# client memory budget = {}", fmt_bytes(CLIENT_MEM_LIMIT));
    println!("{:<7} {:<10} {:>10} {:>14} {:>14} {:>12}", "scale", "mode", "edges", "partials", "seconds", "rate");

    for &scale in scales {
        let params = KroneckerParams::new(scale, 16, 0xF162);
        let g = kronecker_assoc(&params);
        let store = Arc::new(KvStore::new());
        let acc = AccumuloConnector::with_store(store.clone());
        let cfg = D4mTableConfig { degrees: false, transpose: false, ..Default::default() };
        let t = acc.bind("G", &cfg).unwrap();
        t.put_assoc(&g).unwrap();

        // graphulo server-side
        let c = store.create_table("C", vec![]).unwrap();
        let t0 = Instant::now();
        let stats =
            graphulo::table_mult(&t.main(), &t.main(), &c, &TableMultOpts::default()).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{:<7} {:<10} {:>10} {:>14} {:>14.3} {:>12}",
            scale,
            "graphulo",
            g.nnz(),
            stats.partial_products,
            dt,
            fmt_rate(stats.partial_products as f64 / dt)
        );
        records.push(BenchRecord::new(
            "tablemult",
            g.nnz(),
            "graphulo",
            dt,
            stats.partial_products as usize,
        ));

        // graphulo sharded across 2 workers (its own output table so the
        // combiner folds only this run's partials)
        let c2 = store.create_table("C2", vec![]).unwrap();
        let popts = TableMultOpts { workers: 2, ..Default::default() };
        let tp = Instant::now();
        let pstats = graphulo::table_mult(&t.main(), &t.main(), &c2, &popts).unwrap();
        let dt = tp.elapsed().as_secs_f64();
        println!(
            "{:<7} {:<10} {:>10} {:>14} {:>14.3} {:>12}",
            scale,
            "par2",
            g.nnz(),
            pstats.partial_products,
            dt,
            fmt_rate(pstats.partial_products as f64 / dt)
        );
        records.push(BenchRecord::new(
            "tablemult",
            g.nnz(),
            "par2",
            dt,
            pstats.partial_products as usize,
        ));

        // d4m client-side with memory budget
        let ctx = ClientCtx::with_limit(CLIENT_MEM_LIMIT);
        let t1 = Instant::now();
        match ctx.table_mult(&t.main(), &t.main()) {
            Ok(_) => {
                let dt = t1.elapsed().as_secs_f64();
                println!(
                    "{:<7} {:<10} {:>10} {:>14} {:>14.3} {:>12}",
                    scale,
                    "d4m",
                    g.nnz(),
                    stats.partial_products,
                    dt,
                    fmt_rate(stats.partial_products as f64 / dt)
                );
                records.push(BenchRecord::new(
                    "tablemult",
                    g.nnz(),
                    "d4m",
                    dt,
                    stats.partial_products as usize,
                ));
            }
            Err(e) => {
                println!(
                    "{:<7} {:<10} {:>10} {:>14} {:>14} {:>12}",
                    scale, "d4m", g.nnz(), stats.partial_products, "-", format!("OOM ({e})").chars().take(12).collect::<String>()
                );
            }
        }

        // d4m dense path through the native blocked GEMM (small scales
        // only: dense blocks over the full vertex space get huge fast)
        if scale <= 9 {
            let engine = d4m::runtime::DenseEngine::new();
            let t2 = Instant::now();
            let _ = d4m::runtime::blocks::assoc_at_b_dense(&engine, &g, &g, 128).unwrap();
            let dt = t2.elapsed().as_secs_f64();
            println!(
                "{:<7} {:<10} {:>10} {:>14} {:>14.3} {:>12}",
                scale,
                "d4m-dense",
                g.nnz(),
                stats.partial_products,
                dt,
                fmt_rate(stats.partial_products as f64 / dt)
            );
            records.push(BenchRecord::new(
                "tablemult",
                g.nnz(),
                "d4m-dense",
                dt,
                stats.partial_products as usize,
            ));
        }
    }

    let out = Path::new("BENCH_assoc.json");
    match append_records(out, &records) {
        Ok(()) => println!("# appended {} records to {}", records.len(), out.display()),
        Err(e) => eprintln!("# failed to write {}: {e}", out.display()),
    }
}
