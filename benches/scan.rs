//! Bench: the snapshot/streaming scan path — sequential streaming vs.
//! scoped-thread parallel materialisation vs. concurrent readers (with
//! and without writer interference).
//!
//! Scenarios (op = "scan", n = stored entries):
//!   stream      — one full-range lazy `scan_stream`, drained
//!   parallel    — one full-range materialising `scan` (per-tablet
//!                 scoped threads on the 8-way split table)
//!   concurrent4 — 4 reader threads each draining full-range streams
//!                 for a fixed number of passes; aggregate throughput
//!   concurrent4+writer — same, with one writer thread mutating
//!                 throughout (the snapshot path's whole point: readers
//!                 shouldn't serialise against the write path)
//!
//! Machine-readable records are appended to `BENCH_scan.json`;
//! `--smoke` runs the smallest size only (the CI regression probe).

// Bench/example/test scaffolding: unwrap/expect on setup is idiomatic
// here; clippy.toml's disallowed-methods targets library code.
#![allow(clippy::disallowed_methods)]
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use d4m::kvstore::{IterConfig, KvStore, RowRange, Table, TabletConfig};
use d4m::util::bench::{append_records, BenchRecord};
use d4m::util::fmt_rate;

const READERS: usize = 4;
const PASSES: usize = 8;

/// An 8-way split table of `n` entries with flushed runs and a live
/// memtable tail, so scans cross both layers.
fn build_table(store: &KvStore, n: usize) -> Arc<Table> {
    let splits: Vec<String> = (1..8).map(|i| format!("r{:07}", i * n / 8)).collect();
    let t = store.create_table("scan_bench", splits).unwrap();
    for i in 0..n {
        t.put(&format!("r{i:07}"), &format!("c{:02}", i % 17), "1").unwrap();
    }
    t.flush().unwrap();
    // a live unsorted memtable tail (~1/16 of the data) on top
    for i in 0..n / 16 {
        t.put(&format!("r{:07}", i * 16), "c99", "2").unwrap();
    }
    t
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let sizes: &[usize] = if smoke { &[40_000] } else { &[100_000, 400_000, 1_000_000] };
    let mut records: Vec<BenchRecord> = Vec::new();
    println!("# scan path: streaming vs parallel vs concurrent readers");
    println!(
        "{:<10} {:<20} {:>10} {:>12} {:>14}",
        "n", "mode", "entries", "seconds", "rate"
    );

    for &n in sizes {
        let store = KvStore::with_config(TabletConfig::default());
        let t = build_table(&store, n);
        let cfg = IterConfig::default();

        // -- sequential lazy stream
        let t0 = Instant::now();
        let drained = t.scan_stream(&RowRange::all(), &cfg).count();
        let dt = t0.elapsed().as_secs_f64();
        report(&mut records, n, "stream", dt, drained);

        // -- parallel materialising scan (scoped threads per tablet)
        let t1 = Instant::now();
        let collected = t.scan(&RowRange::all(), &cfg).len();
        let dt = t1.elapsed().as_secs_f64();
        assert_eq!(collected, drained, "parallel and streaming scans disagree");
        report(&mut records, n, "parallel", dt, collected);

        // -- concurrent readers, idle write path
        let (dt, total) = run_readers(&t, &cfg, None);
        report(&mut records, n, "concurrent4", dt, total);

        // -- concurrent readers against a live writer (the readers'
        // own drained totals are used: the writer grows the table
        // mid-run, so a pre-writer count would under-report)
        let stop = Arc::new(AtomicBool::new(false));
        let (dt, total) = run_readers(&t, &cfg, Some(stop));
        report(&mut records, n, "concurrent4+writer", dt, total);
    }

    let out = Path::new("BENCH_scan.json");
    match append_records(out, &records) {
        Ok(()) => println!("# appended {} records to {}", records.len(), out.display()),
        Err(e) => eprintln!("# failed to write {}: {e}", out.display()),
    }
}

/// Drain `PASSES` full-range streams on each of `READERS` threads;
/// optionally run a writer thread mutating a hot row set throughout.
/// Returns wall-clock seconds and the aggregate entries drained.
fn run_readers(
    t: &Arc<Table>,
    cfg: &IterConfig,
    writer: Option<Arc<AtomicBool>>,
) -> (f64, usize) {
    let t0 = Instant::now();
    let mut total = 0usize;
    std::thread::scope(|s| {
        if let Some(stop) = writer.clone() {
            let t = t.clone();
            s.spawn(move || {
                let mut i = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    t.put(&format!("w{:05}", i % 1000), "c", &i.to_string()).unwrap();
                    i += 1;
                }
            });
        }
        let readers: Vec<_> = (0..READERS)
            .map(|_| {
                let t = t.clone();
                let cfg = cfg.clone();
                s.spawn(move || {
                    let mut drained = 0usize;
                    for _ in 0..PASSES {
                        drained += t.scan_stream(&RowRange::all(), &cfg).count();
                    }
                    drained
                })
            })
            .collect();
        for r in readers {
            total += r.join().unwrap();
        }
        if let Some(stop) = writer {
            stop.store(true, Ordering::Relaxed);
        }
    });
    (t0.elapsed().as_secs_f64(), total)
}

fn report(records: &mut Vec<BenchRecord>, n: usize, mode: &str, dt: f64, entries: usize) {
    println!(
        "{:<10} {:<20} {:>10} {:>12.3} {:>14}",
        n,
        mode,
        entries,
        dt,
        fmt_rate(entries as f64 / dt)
    );
    records.push(BenchRecord::new("scan", n, mode, dt, entries));
}
